"""Notation parser (paper §III-B): examples + round-trip property."""
from __future__ import annotations

import pytest
from hypo_fallback import given, settings, st

from repro.core.notation import AcceleratorSpec, SegmentSpec, format_spec, parse


def test_paper_examples():
    seg = parse("{L1-L4:CE1, L5-L6:CE2, L7-L9:CE3, L10-L12:CE4}", 12)
    assert len(seg.segments) == 4
    assert seg.segments[0] == SegmentSpec(0, 3, 0, 0)
    assert not seg.segments[0].pipelined

    rr = parse("{L1-Last:CE1-CE4}", 12)
    assert rr.segments[0] == SegmentSpec(0, 11, 0, 3)
    assert rr.segments[0].pipelined
    assert rr.n_ces == 4

    hy = parse("{L1:CE1, L2:CE2, L3:CE3, L4-Last:CE4}", 12)
    assert [s.n_layers for s in hy.segments] == [1, 1, 1, 9]


def test_validation_rejects_gaps():
    with pytest.raises(ValueError):
        parse("{L1-L3:CE1, L5-L12:CE2}", 12)          # gap at L4
    with pytest.raises(ValueError):
        parse("{L1-L4:CE1}", 12)                      # not covering
    with pytest.raises(ValueError):
        parse("{L1-L20:CE1}", 12)                     # out of range


@st.composite
def specs(draw):
    n_layers = draw(st.integers(2, 40))
    n_seg = draw(st.integers(1, min(6, n_layers)))
    cuts = sorted(draw(st.lists(
        st.integers(1, n_layers - 1), min_size=n_seg - 1,
        max_size=n_seg - 1, unique=True)))
    bounds = [0] + cuts + [n_layers]
    segs, ce = [], 0
    for i in range(n_seg):
        lo, hi = bounds[i], bounds[i + 1] - 1
        n_ces = draw(st.integers(1, 3))
        segs.append(SegmentSpec(lo, hi, ce, ce + n_ces - 1))
        ce += n_ces
    return AcceleratorSpec(name="t", segments=tuple(segs)), n_layers


@given(specs())
@settings(max_examples=60, deadline=None)
def test_roundtrip(sn):
    spec, n_layers = sn
    text = format_spec(spec, n_layers)
    back = parse(text, n_layers)
    assert back.segments == spec.segments
