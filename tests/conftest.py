"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests must see the
real single CPU device; only launch/dryrun.py forces 512 placeholders."""
from __future__ import annotations

import pytest


@pytest.fixture(scope="session")
def host_mesh():
    from repro.launch.mesh import make_host_mesh
    return make_host_mesh()


@pytest.fixture(scope="session")
def local_rt(host_mesh):
    from repro.models.runtime import Runtime
    return Runtime(mesh=host_mesh, dp_axes=("data",), tp_axis=None,
                   ep_axis=None)
