"""Chaos suite: the resilience contracts under injected faults.

Every scenario is deterministic (count-based injection, fixed seeds — see
``tests/faults.py``), so each contract is asserted exactly:

* a faulting Pallas backend trips the circuit breaker and the session
  degrades to ``ref`` with bit-identical results;
* a poisoned (NaN) request in a mixed megabatch fails ITS future only;
* deadlines and admission control fail with their specific error codes,
  never by hanging;
* transient faults are retried past, without degrading;
* a search killed mid-run resumes from its checkpoint bit-identically
  (serial, island and multinet loops — the cross-process SIGKILL variant
  lives in ``tests/chaos_kill_resume.py``);
* corrupted/mismatched checkpoints are refused up front.

Contracts and recipes: ``docs/robustness.md``.
"""
from __future__ import annotations

import os
import subprocess
import sys

import numpy as np
import pytest

from faults import (CountingHook, FaultInjected, Killed, inject_fault,
                    kill_after_checkpoints, poison_megabatch)
from repro.api import EvalError, Session, load_checkpoint, save_checkpoint
from repro.cnn.registry import get_cnn
from repro.core.dse.search import SearchConfig, search
from repro.core.multinet.search import MultinetSearchConfig, joint_search
from repro.core.resilience import CircuitBreaker
from repro.fpga.archs import ARCH_NAMES, make_arch
from repro.fpga.boards import get_board

NET = "mobilenetv2"
BOARD = "zc706"


def _specs(net, n_ces=4):
    return [make_arch(a, net, n_ces) for a in ARCH_NAMES]


def _code(excinfo) -> str:
    assert isinstance(excinfo.value, EvalError)
    return excinfo.value.code


# --------------------------------------------------------------------------
# acceptance (a): breaker trips, session degrades to ref, bit-identical
# --------------------------------------------------------------------------
def test_breaker_trips_and_degrades_bit_identical():
    """With the pallas_interpret backend hard-faulting, the first calls
    retryless-fail onto the fallback; after ``fail_threshold`` faults the
    breaker opens and the primary is not even traced any more.  Every
    degraded result is bit-identical to a clean ref session's."""
    net, dev = get_cnn(NET), get_board(BOARD)
    specs = _specs(net)
    # design_tile=13 is unique to this test: no other test compiles it,
    # so every primary attempt really re-traces (and re-faults)
    ses = Session(dev, backend="pallas_interpret", design_tile=13,
                  fallback_backend="ref", max_retries=0)
    ref = Session(dev, backend="ref", design_tile=13)
    want = ref.evaluate(specs, net)

    hook = CountingHook(backend="pallas_interpret")   # always fault
    with inject_fault(hook):
        for call in range(1, 6):
            out = ses.evaluate(specs, net)
            for k in want:
                np.testing.assert_array_equal(
                    np.asarray(out[k]), np.asarray(want[k]),
                    err_msg=f"degraded call {call}, metric {k}")
            if call >= ses.breaker.fail_threshold:
                assert ses.breaker.is_open
    # one primary trace per call until the trip, then none: calls 4 and 5
    # went straight to the fallback without touching the faulty kernel
    assert hook.calls == ses.breaker.fail_threshold
    assert ses.stats.degraded == 5
    assert ses.compile_stats()["degraded"] == 5

    # recovery: the fault clears (hook uninstalled); the breaker's
    # periodic probe retries the primary and closes again
    assert ses.breaker.is_open
    for _ in range(ses.breaker.probe_interval):
        out = ses.evaluate(specs, net)
    assert not ses.breaker.is_open, \
        "recovery probe never re-armed the breaker"
    # once closed, the primary serves again (pallas_interpret is
    # bit-identical to ref by the kernel parity tests)
    degraded_before = ses.stats.degraded
    out = ses.evaluate(specs, net)
    assert ses.stats.degraded == degraded_before
    for k in want:
        np.testing.assert_array_equal(np.asarray(out[k]),
                                      np.asarray(want[k]))


def test_search_backend_degrades_while_breaker_open():
    """explore() consults the breaker without spending recovery probes:
    open -> the whole search runs on the fallback and still succeeds."""
    net, dev = get_cnn(NET), get_board(BOARD)
    ses = Session(dev, backend="pallas_interpret", fallback_backend="ref")
    ses.breaker = CircuitBreaker(fail_threshold=1, probe_interval=8)
    hook = CountingHook(backend="pallas_interpret")
    with inject_fault(hook):
        with pytest.raises(EvalError) as ei:
            Session(dev, backend="pallas_interpret", fallback_backend=None,
                    design_tile=19).evaluate(_specs(net), net)
        assert _code(ei) == EvalError.BACKEND_FAULT
        ses.breaker.record_failure()          # trip this session's breaker
        assert ses.breaker.is_open
        res = ses.explore(net, n=256, strategy="search",
                          config=SearchConfig(pop_size=128, seed=0))
    assert res.n_evals == 256
    assert ses.stats.degraded == 1


# --------------------------------------------------------------------------
# acceptance (b): one poisoned request fails only its own future
# --------------------------------------------------------------------------
def test_poisoned_request_fails_only_its_future():
    # distinct nets: the coalescer merges same-(net, board) requests into
    # one chunk, and the poisoner corrupts a whole chunk — different nets
    # keep the victim in its own chunk (within-chunk NaN isolation is
    # covered by tests/test_serve_coalesce.py)
    net, net2, dev = get_cnn(NET), get_cnn("resnet50"), get_board(BOARD)
    ses = Session(dev, linger_s=0.5)
    with poison_megabatch(job_index=0, key="latency_s"):
        f_bad = ses.submit(["{L1-Last:CE1-CE4}"], net)
        f_good = ses.submit(_specs(net2), net2)
        with pytest.raises(EvalError, match="non-finite") as ei:
            f_bad.result(timeout=120)
        assert _code(ei) == EvalError.NONFINITE_METRICS
        good = f_good.result(timeout=120)
    ses.close()
    want = ses.evaluate(_specs(net2), net2)
    for k in want:
        np.testing.assert_array_equal(np.asarray(good[k]),
                                      np.asarray(want[k]))
    assert ses.stats.megabatches >= 1


# --------------------------------------------------------------------------
# acceptance (d): deadlines and admission control fail fast, never hang
# --------------------------------------------------------------------------
def test_deadline_exceeded_fails_with_its_code():
    net, dev = get_cnn(NET), get_board(BOARD)
    with Session(dev, linger_s=0.3) as ses:
        fut = ses.submit("{L1-Last:CE1-CE4}", net, deadline_s=0.01)
        with pytest.raises(EvalError, match="deadline") as ei:
            fut.result(timeout=120)
        assert _code(ei) == EvalError.DEADLINE_EXCEEDED
        assert ses.stats.deadline_missed == 1
        # a submit under a generous deadline still completes
        out = ses.submit("{L1-Last:CE1-CE4}", net,
                         deadline_s=300.0).result(timeout=300)
        assert np.isfinite(out["latency_s"])
    assert ses.compile_stats()["deadline_missed"] == 1


def test_queue_full_rejects_with_its_code():
    net, dev = get_cnn(NET), get_board(BOARD)
    # a long linger holds the first request in the queue while the second
    # submit arrives, so admission control sees a deterministic queue depth
    ses = Session(dev, max_queue=1, linger_s=1.0)
    f1 = ses.submit(_specs(net), net)
    with pytest.raises(EvalError, match="queue full") as ei:
        ses.submit(_specs(net), net)
    assert _code(ei) == EvalError.QUEUE_FULL
    assert ses.stats.rejected == 1
    out = f1.result(timeout=300)              # the admitted one completes
    assert np.isfinite(np.asarray(out["latency_s"])).all()
    ses.close()


# --------------------------------------------------------------------------
# retries: transient faults are absorbed without degrading
# --------------------------------------------------------------------------
def test_transient_fault_retried_past_without_degrading():
    net, dev = get_cnn(NET), get_board(BOARD)
    ses = Session(dev, backend="pallas_interpret", design_tile=17,
                  fallback_backend="ref", max_retries=2)
    hook = CountingHook(fail_first_n=2, backend="pallas_interpret")
    with inject_fault(hook):
        out = ses.evaluate(_specs(net), net)
    assert hook.calls == 3                    # 2 faults + 1 clean trace
    assert ses.stats.retried == 2
    assert ses.stats.degraded == 0
    assert not ses.breaker.is_open            # success reset the breaker
    want = Session(dev, backend="ref").evaluate(_specs(net), net)
    for k in want:
        np.testing.assert_array_equal(np.asarray(out[k]),
                                      np.asarray(want[k]))


# --------------------------------------------------------------------------
# acceptance (c): kill mid-search, resume bit-identical (in-process;
# the SIGKILL + REPRO_MESH_DEVICES=4 variant: tests/chaos_kill_resume.py)
# --------------------------------------------------------------------------
def _assert_same_search(a, b):
    np.testing.assert_array_equal(a.front_idx, b.front_idx)
    np.testing.assert_array_equal(a.points, b.points)
    for k in a.metrics:
        np.testing.assert_array_equal(a.metrics[k], b.metrics[k])
    assert len(a.history) == len(b.history)
    for ha, hb in zip(a.history, b.history):
        for k in ha:
            if k != "elapsed_s":
                np.testing.assert_array_equal(ha[k], hb[k])


@pytest.mark.parametrize("islands", [None, 2],
                         ids=["serial", "island2"])
def test_search_killed_and_resumed_bit_identical(islands, tmp_path):
    net, dev = get_cnn(NET), get_board(BOARD)
    # both variants run >= 5 generations, so interval-2 checkpointing
    # writes twice (gens 2 and 4) before the simulated crash
    base = dict(pop_size=32, budget=192, seed=3, n_islands=islands) \
        if islands is None else \
        dict(pop_size=16, budget=160, seed=3, n_islands=islands,
             migration_interval=2, migration_elites=4)
    plain = search(net, dev, SearchConfig(**base))
    ckpt = str(tmp_path / "dse.ckpt")
    cfg = SearchConfig(**base, checkpoint_path=ckpt, checkpoint_interval=2)
    with kill_after_checkpoints(2) as wrote:
        with pytest.raises(Killed):
            search(net, dev, cfg)
    assert wrote["writes"] == 2
    resumed = search(net, dev,
                     SearchConfig(**{**base, "checkpoint_path": ckpt,
                                     "checkpoint_interval": 2,
                                     "resume": True}))
    _assert_same_search(plain, resumed)
    if islands:
        assert len(resumed.island_fronts) == islands
        for fa, fb in zip(plain.island_fronts, resumed.island_fronts):
            np.testing.assert_array_equal(fa, fb)


def test_multinet_search_killed_and_resumed_bit_identical(tmp_path):
    nets = [get_cnn(NET), get_cnn("resnet50")]
    dev = get_board(BOARD)
    base = dict(pop_size=16, budget=96, seed=2, mode="spatial")
    plain = joint_search(nets, dev, MultinetSearchConfig(**base))
    ckpt = str(tmp_path / "mn.ckpt")
    with kill_after_checkpoints(2):
        with pytest.raises(Killed):
            joint_search(nets, dev, MultinetSearchConfig(
                **base, checkpoint_path=ckpt, checkpoint_interval=2))
    resumed = joint_search(nets, dev, MultinetSearchConfig(
        **base, checkpoint_path=ckpt, checkpoint_interval=2, resume=True))
    _assert_same_search(plain, resumed)
    for r in plain.shares:
        np.testing.assert_array_equal(plain.shares[r], resumed.shares[r])


# the real thing: a worker SIGKILLs itself mid-search; a fresh process
# resumes bit-identically (island mode under REPRO_MESH_DEVICES=4)
@pytest.mark.slow
@pytest.mark.parametrize("mode", ["serial", "island"])
def test_sigkill_and_resume_subprocess(mode):
    script = os.path.join(os.path.dirname(__file__), "chaos_kill_resume.py")
    out = subprocess.run([sys.executable, script, mode],
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, \
        f"chaos driver {mode} failed:\n{out.stdout}\n{out.stderr}"
    assert f"CHAOS_OK {mode}" in out.stdout


# --------------------------------------------------------------------------
# checkpoint integrity: corruption and mismatches are refused up front
# --------------------------------------------------------------------------
def test_corrupt_checkpoint_refused(tmp_path):
    path = str(tmp_path / "x.ckpt")
    save_checkpoint(path, "dse-search", {"gen": 3}, meta={"fingerprint": 1})
    assert load_checkpoint(path, kind="dse-search")["state"]["gen"] == 3
    with open(path, "r+b") as f:              # flip one payload byte
        f.seek(-1, 2)
        last = f.read(1)
        f.seek(-1, 2)
        f.write(bytes([last[0] ^ 0xFF]))
    with pytest.raises(EvalError, match="checksum") as ei:
        load_checkpoint(path, kind="dse-search")
    assert _code(ei) == EvalError.INVALID_INPUT


def test_wrong_kind_and_fingerprint_refused(tmp_path):
    path = str(tmp_path / "x.ckpt")
    save_checkpoint(path, "dse-search", {"gen": 1}, meta={"fingerprint": 1})
    with pytest.raises(EvalError, match="kind"):
        load_checkpoint(path, kind="multinet-search")
    # a resume under different search settings is refused, not misapplied
    net, dev = get_cnn(NET), get_board(BOARD)
    cfg = SearchConfig(pop_size=32, budget=128, seed=3,
                       checkpoint_path=str(tmp_path / "fp.ckpt"),
                       checkpoint_interval=2)
    with kill_after_checkpoints(1):
        with pytest.raises(Killed):
            search(net, dev, cfg)
    with pytest.raises(EvalError, match="different search") as ei:
        search(net, dev, SearchConfig(
            pop_size=32, budget=128, seed=4,        # different seed
            checkpoint_path=cfg.checkpoint_path,
            checkpoint_interval=2, resume=True))
    assert _code(ei) == EvalError.INVALID_INPUT
