"""Vectorized MCCM vs the scalar reference — the central exactness claim."""
from __future__ import annotations

import numpy as np
import pytest

from repro.cnn.registry import CNN_NAMES, get_cnn
from repro.core.batch_eval import encode_specs, evaluate_specs, make_tables
from repro.core.dse import decode_design, explore, pareto, sample_mixed
from repro.core.evaluator import evaluate_design
from repro.fpga.archs import ARCH_NAMES, make_arch
from repro.fpga.boards import get_board

METRICS = ("latency_s", "throughput_ips", "buffer_bytes", "access_bytes")
RTOL = {"latency_s": 1e-4, "throughput_ips": 1e-4,
        "buffer_bytes": 1e-4, "access_bytes": 0.04}  # f32 threshold flips


def _scalar_vals(m):
    return {"latency_s": m.latency_s, "throughput_ips": m.throughput_ips,
            "buffer_bytes": float(m.buffer_bytes),
            "access_bytes": m.access_bytes}


@pytest.mark.parametrize("cnn", CNN_NAMES)
def test_matches_scalar_on_templates(cnn):
    net = get_cnn(cnn)
    dev = get_board("vcu108")
    specs = [make_arch(a, net, n) for a in ARCH_NAMES for n in (2, 5, 9, 11)]
    scalar = [evaluate_design(s, net, dev) for s in specs]
    batch = evaluate_specs(specs, net, dev)
    for i, s in enumerate(scalar):
        sv = _scalar_vals(s)
        for k in METRICS:
            np.testing.assert_allclose(
                float(batch[k][i]), sv[k], rtol=RTOL[k],
                err_msg=f"{cnn} {specs[i].name} {k}")


def test_matches_scalar_on_random_mixed_designs():
    net = get_cnn("resnet50")
    dev = get_board("zc706")
    rng = np.random.default_rng(7)
    db = sample_mixed(rng, len(net), 24)
    batch = {k: np.asarray(v) for k, v in
             evaluate_specs([decode_design(db, i, len(net))
                             for i in range(24)], net, dev).items()}
    for i in range(24):
        spec = decode_design(db, i, len(net))
        m = evaluate_design(spec, net, dev,
                            inter_segment_pipelining=bool(db.inter_pipe[i]))
        sv = _scalar_vals(m)
        for k in METRICS:
            np.testing.assert_allclose(
                float(batch[k][i]), sv[k], rtol=RTOL[k],
                err_msg=f"design {i} {k}")


def test_pareto_front_is_nondominated():
    pts = np.array([[1, 5], [2, 4], [3, 3], [2, 2], [5, 1], [4, 4]])
    idx = pareto(pts)
    front = pts[idx]
    for i, p in enumerate(front):
        for q in front:
            assert not (np.all(q <= p) and np.any(q < p))
    # (2,2) dominates (3,3) and (4,4)
    assert [2, 2] in front.tolist()
    assert [3, 3] not in front.tolist()


def test_explore_speed_and_consistency():
    net = get_cnn("resnet50")
    dev = get_board("vcu110")
    res = explore(net, dev, n=2048, family="custom", seed=3)
    assert res.per_design_us < 6300          # beat the paper's 6.3 ms
    m = res.metrics
    assert np.all(m["latency_s"] > 0)
    assert np.all(m["throughput_ips"] * m["latency_s"] >= 0.99)
