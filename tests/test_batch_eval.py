"""Vectorized MCCM vs the scalar reference — the central exactness claim."""
from __future__ import annotations

import numpy as np
import pytest

from repro.cnn.registry import CNN_NAMES, get_cnn
from repro.core.batch_eval import encode_specs, evaluate_specs, make_tables
from repro.core.dse import decode_design, explore, pareto, sample_mixed
from repro.core.evaluator import evaluate_design
from repro.fpga.archs import ARCH_NAMES, make_arch
from repro.fpga.boards import get_board

METRICS = ("latency_s", "throughput_ips", "buffer_bytes", "access_bytes")
RTOL = {"latency_s": 1e-4, "throughput_ips": 1e-4,
        "buffer_bytes": 1e-4, "access_bytes": 0.04}  # f32 threshold flips


def _scalar_vals(m):
    return {"latency_s": m.latency_s, "throughput_ips": m.throughput_ips,
            "buffer_bytes": float(m.buffer_bytes),
            "access_bytes": m.access_bytes}


@pytest.mark.parametrize("cnn", CNN_NAMES)
def test_matches_scalar_on_templates(cnn):
    net = get_cnn(cnn)
    dev = get_board("vcu108")
    specs = [make_arch(a, net, n) for a in ARCH_NAMES for n in (2, 5, 9, 11)]
    scalar = [evaluate_design(s, net, dev) for s in specs]
    batch = evaluate_specs(specs, net, dev)
    for i, s in enumerate(scalar):
        sv = _scalar_vals(s)
        for k in METRICS:
            np.testing.assert_allclose(
                float(batch[k][i]), sv[k], rtol=RTOL[k],
                err_msg=f"{cnn} {specs[i].name} {k}")


def test_matches_scalar_on_random_mixed_designs():
    net = get_cnn("resnet50")
    dev = get_board("zc706")
    rng = np.random.default_rng(7)
    db = sample_mixed(rng, len(net), 24)
    batch = {k: np.asarray(v) for k, v in
             evaluate_specs([decode_design(db, i, len(net))
                             for i in range(24)], net, dev).items()}
    for i in range(24):
        spec = decode_design(db, i, len(net))
        m = evaluate_design(spec, net, dev,
                            inter_segment_pipelining=bool(db.inter_pipe[i]))
        sv = _scalar_vals(m)
        for k in METRICS:
            np.testing.assert_allclose(
                float(batch[k][i]), sv[k], rtol=RTOL[k],
                err_msg=f"design {i} {k}")


def test_pareto_front_is_nondominated():
    pts = np.array([[1, 5], [2, 4], [3, 3], [2, 2], [5, 1], [4, 4]])
    idx = pareto(pts)
    front = pts[idx]
    for i, p in enumerate(front):
        for q in front:
            assert not (np.all(q <= p) and np.any(q < p))
    # (2,2) dominates (3,3) and (4,4)
    assert [2, 2] in front.tolist()
    assert [3, 3] not in front.tolist()


def test_explore_speed_and_consistency():
    net = get_cnn("resnet50")
    dev = get_board("vcu110")
    res = explore(net, dev, n=2048, family="custom", seed=3)
    assert res.per_design_us < 6300          # beat the paper's 6.3 ms
    m = res.metrics
    assert np.all(m["latency_s"] > 0)
    assert np.all(m["throughput_ips"] * m["latency_s"] >= 0.99)


def test_fused_path_backends_bit_identical():
    """The Pallas kernel (interpret mode — what TPU runs, on CPU) and the
    pure-jnp ref produce bit-identical metrics through evaluate_batch."""
    from repro.core.batch_eval import evaluate_batch, make_tables

    net = get_cnn("xception")
    dev = get_board("zc706")
    rng = np.random.default_rng(11)
    db = sample_mixed(rng, len(net), 48)
    tables = make_tables(net)
    ref = evaluate_batch(db, tables, dev, backend="ref")
    pal = evaluate_batch(db, tables, dev, backend="pallas_interpret")
    for k in ref:
        np.testing.assert_array_equal(
            np.asarray(ref[k]), np.asarray(pal[k]), err_msg=k)


def test_matches_scalar_through_pallas_interpret():
    """Scalar parity holds through the fused kernel path itself."""
    net = get_cnn("mobilenetv2")
    dev = get_board("vcu108")
    specs = [make_arch(a, net, n) for a in ARCH_NAMES for n in (2, 9)]
    batch = evaluate_specs(specs, net, dev, backend="pallas_interpret")
    for i, s in enumerate(specs):
        sv = _scalar_vals(evaluate_design(s, net, dev))
        for k in METRICS:
            np.testing.assert_allclose(
                float(batch[k][i]), sv[k], rtol=RTOL[k],
                err_msg=f"{s.name} {k}")


def test_one_compile_serves_all_cnns_and_boards():
    """The recompile-free claim, asserted: NetTables / DeviceTables are
    traced pytrees padded to shared shapes, so ONE jit compile evaluates
    every registered CNN on every registered board."""
    import jax

    from repro.core import batch_eval
    from repro.core.batch_eval import evaluate_batch, make_tables
    from repro.fpga.boards import BOARD_NAMES

    jax.clear_caches()
    assert batch_eval._evaluate_jit._cache_size() == 0
    rng = np.random.default_rng(5)
    for cnn in CNN_NAMES:
        net = get_cnn(cnn)
        tables = make_tables(net)
        db = sample_mixed(rng, len(net), 64)
        for board in BOARD_NAMES:
            out = evaluate_batch(db, tables, get_board(board))
            assert np.isfinite(np.asarray(out["latency_s"])).all()
    assert batch_eval._evaluate_jit._cache_size() == 1


def test_evaluate_specs_multi_matches_single_jobs():
    """The cross-(CNN × board) megabatch returns exactly what per-job
    evaluation returns."""
    from repro.core.batch_eval import evaluate_specs_multi

    jobs = []
    for cnn, board in (("mobilenetv2", "zc706"), ("xception", "vcu110")):
        net = get_cnn(cnn)
        jobs.append(([make_arch(a, net, 4) for a in ARCH_NAMES], net,
                     get_board(board)))
    multi = evaluate_specs_multi(jobs)
    for (specs, net, dev), got in zip(jobs, multi):
        want = evaluate_specs(specs, net, dev)
        for k in want:
            np.testing.assert_array_equal(got[k], want[k], err_msg=k)


def test_evaluate_specs_tail_padding_exact():
    """Chunked evaluation with a ragged tail equals unchunked evaluation
    (padded rows are sliced off, not leaked)."""
    net = get_cnn("mobilenetv2")
    dev = get_board("zc706")
    rng = np.random.default_rng(13)
    db = sample_mixed(rng, len(net), 37)
    specs = [decode_design(db, i, len(net)) for i in range(37)]
    whole = evaluate_specs(specs, net, dev, chunk=2048)
    ragged = evaluate_specs(specs, net, dev, chunk=16)
    for k in whole:
        np.testing.assert_array_equal(whole[k], ragged[k], err_msg=k)
        assert len(ragged[k]) == 37
