"""CNN zoo vs paper Table III (layer counts and weight totals)."""
from __future__ import annotations

import pytest

from repro.cnn.registry import CNN_NAMES, TABLE_III, get_cnn, total_params


@pytest.mark.parametrize("name", CNN_NAMES)
def test_layer_counts_match_table3(name):
    _, weights_m, conv_layers = TABLE_III[name]
    net = get_cnn(name)
    assert len(net) == conv_layers


@pytest.mark.parametrize("name", CNN_NAMES)
def test_weight_counts_match_table3(name):
    _, weights_m, _ = TABLE_III[name]
    total = total_params(name) / 1e6
    assert total == pytest.approx(weights_m, rel=0.06), \
        f"{name}: {total:.1f}M vs Table III {weights_m}M"


def test_geometry_sane():
    """Dims positive, spatial sizes shrink monotonically-ish, MACs > 0.
    (Exact channel chaining doesn't hold for branch/concat topologies —
    shortcut convs and DenseNet growth break the linear chain.)"""
    for name in CNN_NAMES:
        net = get_cnn(name)
        for l in net:
            assert l.in_ch > 0 and l.out_ch > 0 and l.macs > 0
            assert l.oh <= l.ih and l.ow <= l.iw
        assert net.layers[0].ih >= net.layers[-1].ih
