"""core/shard.py: design-axis sharding + the island-model search.

Two layers:

* in-process tests run on whatever this interpreter sees (usually one
  CPU device — conftest never sets XLA_FLAGS): padding math, env
  resolution, the single-device fallback, and the island model, which
  works on any device count (islands fall back to the serial loop).
* subprocess tests spawn tests/shard_worker.py with REPRO_MESH_DEVICES=8
  and deliberately WITHOUT XLA_FLAGS — proving the documented env-var
  path splits the host platform by itself — then assert bit-parity,
  cache stability and sharded-vs-serial island equality on real
  multi-device meshes.  CI's shard-smoke job additionally runs the
  ``needs_devices`` tests in-process under a forced 4-device host.
"""
from __future__ import annotations

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.batch_eval import padded_rows
from repro.core.shard import (EvalMesh, MESH_ENV, env_mesh_devices,
                              force_host_devices)

SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")
WORKER = os.path.join(os.path.dirname(__file__), "shard_worker.py")


def _ndev():
    import jax
    return len(jax.devices())


needs_devices = pytest.mark.skipif(
    _ndev() < 2,
    reason="needs a multi-device backend (CI shard-smoke forces 4)")


# -------------------------------------------------------------------------
# padding math + env resolution (pure host-side, no devices involved)
# -------------------------------------------------------------------------
def test_padded_rows_rounds_to_device_tile_unit():
    assert padded_rows(100, 8) == 104          # single device: tile only
    assert padded_rows(100, 8, 1) == 104
    assert padded_rows(100, 8, 4) == 128       # unit = tile * ndevices
    assert padded_rows(1, 128, 8) == 1024
    assert padded_rows(1024, 128, 8) == 1024   # exact multiples untouched
    assert padded_rows(1025, 128, 8) == 2048


def test_env_mesh_devices(monkeypatch):
    monkeypatch.delenv(MESH_ENV, raising=False)
    assert env_mesh_devices() is None
    monkeypatch.setenv(MESH_ENV, "4")
    assert env_mesh_devices() == 4
    monkeypatch.setenv(MESH_ENV, "0")
    with pytest.raises(ValueError):
        env_mesh_devices()
    monkeypatch.setenv(MESH_ENV, "lots")
    with pytest.raises(ValueError):
        env_mesh_devices()


def test_force_host_devices_is_idempotent(monkeypatch):
    monkeypatch.setenv("XLA_FLAGS", "--xla_foo=1")
    force_host_devices(4)
    first = os.environ["XLA_FLAGS"]
    assert "--xla_force_host_platform_device_count=4" in first
    force_host_devices(8)                       # flag present: no-op
    assert os.environ["XLA_FLAGS"] == first
    monkeypatch.setenv("XLA_FLAGS", "")
    force_host_devices(1)                       # n < 2: no-op
    assert "device_count" not in os.environ["XLA_FLAGS"]


# -------------------------------------------------------------------------
# single-device fallback: mesh must be a bit-exact no-op
# -------------------------------------------------------------------------
def test_single_device_mesh_is_identity():
    from repro.cnn.registry import get_cnn
    from repro.core.batch_eval import encode_specs, evaluate_batch, \
        make_tables
    from repro.fpga.archs import ARCH_NAMES, make_arch
    from repro.fpga.boards import get_board

    mesh = EvalMesh(ndevices=1)
    assert not mesh.is_sharded
    assert mesh.padded_rows(100, 8) == padded_rows(100, 8)
    net = get_cnn("mobilenetv2")
    specs = [make_arch(a, net, n) for a in ARCH_NAMES for n in (2, 5)]
    batch = encode_specs(specs, len(net))
    tables = make_tables(net)
    dev = get_board("vcu108")
    plain = evaluate_batch(batch, tables, dev, tile=8)
    meshed = evaluate_batch(batch, tables, dev, tile=8, mesh=mesh)
    for k in plain:
        np.testing.assert_array_equal(np.asarray(plain[k]),
                                      np.asarray(meshed[k]))


def test_evalmesh_clamps_to_visible_devices():
    mesh = EvalMesh(ndevices=64)                # asks for more than exist
    assert mesh.requested == 64
    assert mesh.ndevices == _ndev()
    assert len(mesh.devices) == mesh.ndevices


# -------------------------------------------------------------------------
# island model (device-count independent: serial loop on one device)
# -------------------------------------------------------------------------
def _island_cfg(**kw):
    from repro.core.dse.search import SearchConfig
    base = dict(pop_size=64, budget=1300, seed=3, n_islands=4,
                migration_interval=2, migration_elites=4)
    base.update(kw)
    return SearchConfig(**base)


@pytest.fixture(scope="module")
def island_result():
    from repro.cnn.registry import get_cnn
    from repro.core.dse.search import search
    from repro.fpga.boards import get_board
    return search(get_cnn("mobilenetv2"), get_board(), _island_cfg())


def test_island_search_is_deterministic(island_result):
    from repro.cnn.registry import get_cnn
    from repro.core.dse.search import search
    from repro.fpga.boards import get_board
    again = search(get_cnn("mobilenetv2"), get_board(), _island_cfg())
    np.testing.assert_array_equal(island_result.front_idx, again.front_idx)
    np.testing.assert_array_equal(island_result.points, again.points)


def test_island_search_spends_exact_budget(island_result):
    cfg = _island_cfg()
    assert island_result.n_evals == cfg.budget
    assert len(island_result.batch.seg_end) == cfg.budget
    assert len(island_result.island_fronts) == cfg.n_islands


def test_migration_transfers_elites(island_result):
    migrated = [h["migrants"] for h in island_result.history]
    assert sum(migrated) > 0, "no generation exchanged elites"
    assert migrated[-1] == 0                    # final gen never breeds


def test_merged_front_dominates_island_fronts(island_result):
    merged = island_result.points[island_result.front_idx]
    for fi in island_result.island_fronts:
        assert len(fi) > 0
        for p in island_result.points[fi]:
            assert (merged <= p).all(axis=1).any(), \
                f"island point {p} beats the merged front"


def test_seed_changes_island_outcome(island_result):
    from repro.cnn.registry import get_cnn
    from repro.core.dse.search import search
    from repro.fpga.boards import get_board
    other = search(get_cnn("mobilenetv2"), get_board(),
                   _island_cfg(seed=4))
    assert not (other.points.shape == island_result.points.shape
                and np.array_equal(other.points, island_result.points))


# -------------------------------------------------------------------------
# in-process multi-device checks (CI shard-smoke: 4 forced host devices)
# -------------------------------------------------------------------------
@needs_devices
def test_sharded_parity_in_process():
    from repro.cnn.registry import get_cnn
    from repro.core.batch_eval import encode_specs, evaluate_batch, \
        make_tables
    from repro.fpga.archs import ARCH_NAMES, make_arch
    from repro.fpga.boards import get_board

    mesh = EvalMesh()
    assert mesh.is_sharded
    net = get_cnn("resnet50")
    specs = [make_arch(a, net, n) for a in ARCH_NAMES for n in (2, 5, 9)]
    batch = encode_specs(specs, len(net))
    tables = make_tables(net)
    dev = get_board("zc706")
    plain = evaluate_batch(batch, tables, dev, tile=8)
    sharded = evaluate_batch(batch, tables, dev, tile=8, mesh=mesh)
    for k in plain:
        np.testing.assert_array_equal(np.asarray(plain[k]),
                                      np.asarray(sharded[k]))


@needs_devices
def test_sharded_session_reuses_compiles():
    from repro.cnn.registry import get_cnn
    from repro.core.session import EvalConfig, Session
    from repro.fpga.boards import get_board

    ses = Session(get_board(), config=EvalConfig(tile=8))
    assert ses.mesh.is_sharded
    net = get_cnn("mobilenetv2")
    spec = "{L1-L20:CE1, L21-Last:CE2}"
    ses.evaluate([spec] * 100, net)
    warm = ses.compile_stats()
    assert warm["mesh_evaluate_batch"] >= 1
    ses.evaluate([spec] * 97, net)              # same pad bucket
    assert ses.compile_stats() == warm


# -------------------------------------------------------------------------
# subprocess: the documented env-var path, 8 devices, no manual XLA_FLAGS
# -------------------------------------------------------------------------
def _run_worker(job: str) -> str:
    env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
    env[MESH_ENV] = "8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, WORKER, job], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, \
        f"worker {job} failed:\n{out.stdout}\n{out.stderr}"
    assert f"WORKER_OK {job}" in out.stdout
    return out.stdout


@pytest.mark.slow
def test_worker_parity_all_archs_all_cnns():
    _run_worker("parity")


@pytest.mark.slow
def test_worker_island_sharded_equals_serial():
    _run_worker("islands")


@pytest.mark.slow
def test_worker_session_cache_stability():
    _run_worker("cache")
