"""Documentation front-door checks: the README and docs exist, every
relative markdown cross-link resolves to a real file, and the paths named
in the subsystem tables exist in the tree.

Runs standalone (``python tests/test_docs.py``) with no third-party
dependencies, so CI can gate docs without installing the package.
"""
from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: markdown files whose links are checked (all must exist)
DOC_FILES = ("README.md", "docs/index.md", "docs/api.md",
             "docs/architecture.md", "docs/perf.md", "docs/dse.md",
             "docs/multinet.md", "docs/robustness.md",
             "docs/observability.md", "docs/serving.md",
             "docs/schedule.md")

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
#: `path`-style mentions of repo files in the docs' tables/prose
_CODEPATH = re.compile(
    r"`((?:src|benchmarks|examples|tests|artifacts|docs)/[\w./-]+"
    r"\.(?:py|md|json))`")


def iter_doc_issues():
    """Yield human-readable problem strings (empty = docs are healthy)."""
    for rel in DOC_FILES:
        path = os.path.join(REPO, rel)
        if not os.path.isfile(path):
            yield f"{rel}: missing"
            continue
        text = open(path, encoding="utf-8").read()
        base = os.path.dirname(path)
        for target in _LINK.findall(text):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            file_part = target.split("#", 1)[0]
            if not file_part:          # same-file anchor
                continue
            if not os.path.exists(os.path.join(base, file_part)):
                yield f"{rel}: broken link -> {target}"
        for code in _CODEPATH.findall(text):
            if not os.path.exists(os.path.join(REPO, code)):
                yield f"{rel}: names nonexistent path `{code}`"


def test_docs_front_door_exists_and_links_resolve():
    issues = list(iter_doc_issues())
    assert not issues, "\n".join(issues)


def test_readme_covers_front_door():
    """The README carries the pieces the docs index relies on: quickstart
    command, subsystem map and the paper-correspondence table."""
    text = open(os.path.join(REPO, "README.md"), encoding="utf-8").read()
    for needle in ("pytest", "docs/index.md", "benchmarks.run",
                   "fig9_fig10_dse", "tab5_best_arch", "multinet_hybrid"):
        assert needle in text, f"README.md lacks {needle!r}"


if __name__ == "__main__":
    problems = list(iter_doc_issues())
    for p in problems:
        print("DOCS:", p)
    print(f"docs check: {len(DOC_FILES)} files, "
          f"{len(problems)} problem(s)")
    sys.exit(1 if problems else 0)
