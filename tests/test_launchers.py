"""Integration tests: train/serve drivers and the dry-run, as subprocesses
(the dry-run needs its own process for the 512-device XLA flag)."""
from __future__ import annotations

import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")}


def _run(args, timeout=560):
    return subprocess.run([sys.executable, "-m", *args], cwd=ROOT, env=ENV,
                          capture_output=True, text=True, timeout=timeout)


def test_train_driver_crash_restart(tmp_path):
    ck = str(tmp_path / "ck")
    r1 = _run(["repro.launch.train", "--arch", "qwen1.5-0.5b", "--reduced",
               "--steps", "25", "--ckpt-dir", ck, "--ckpt-every", "10",
               "--crash-at", "15"])
    assert r1.returncode == 42, r1.stderr[-800:]
    assert "committed step 10" in r1.stdout
    r2 = _run(["repro.launch.train", "--arch", "qwen1.5-0.5b", "--reduced",
               "--steps", "25", "--ckpt-dir", ck, "--ckpt-every", "10"])
    assert r2.returncode == 0, r2.stderr[-800:]
    assert "resumed from committed step 10" in r2.stdout
    assert "done: 15 steps" in r2.stdout


def test_serve_driver(tmp_path):
    r = _run(["repro.launch.serve", "--arch", "llama3.2-1b", "--reduced",
              "--batch", "2", "--new-tokens", "6"])
    assert r.returncode == 0, r.stderr[-800:]
    assert "tok/s" in r.stdout


@pytest.mark.slow
def test_dryrun_one_cell(tmp_path):
    """Lower+compile one real cell on the 512-device production mesh."""
    out = str(tmp_path / "art")
    r = _run(["repro.launch.dryrun", "--arch", "whisper-base",
              "--shape", "train_4k", "--mesh", "single", "--out", out])
    assert r.returncode == 0, (r.stdout[-500:], r.stderr[-800:])
    rec = json.load(open(os.path.join(
        out, "whisper-base__train_4k__single.json")))
    assert rec["ok"]
    assert rec["walk"]["flops"] > 0
    assert rec["collectives"]["total_wire"] > 0
