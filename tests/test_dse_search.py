"""The DSE subsystem: vectorized samplers, encoding round-trip, vectorized
pareto vs the seed reference, and the guided search loop."""
from __future__ import annotations

import numpy as np
import pytest

from repro.cnn.registry import get_cnn
from repro.core.dse import (
    NS,
    DesignBatch,
    ParetoArchive,
    SearchConfig,
    decode_design,
    encode_specs,
    explore,
    make_children,
    orient,
    pareto,
    sample_custom,
    sample_custom_loop,
    sample_mixed,
    sample_mixed_loop,
    search,
    validate_batch,
)
from repro.core.evaluator import evaluate_design
from repro.fpga.boards import get_board

OBJ = ("latency_s", "buffer_bytes")


# ------------------------------------------------------------------ samplers
@pytest.mark.parametrize("n_layers", [4, 13, 52])
@pytest.mark.parametrize("family", ["custom", "mixed"])
def test_samplers_valid_and_canonical(family, n_layers):
    rng = np.random.default_rng(0)
    f = sample_custom if family == "custom" else sample_mixed
    batch = f(rng, n_layers, 3000)
    assert validate_batch(batch, n_layers, min_ces=1, max_ces=11).all()


@pytest.mark.parametrize("n_layers", [1, 2, 3, 5])
def test_sample_custom_degenerate_small_net(n_layers):
    """Regression: with few layers the pipelined head used to consume every
    layer (or run past the end) and emit out-of-range segments."""
    rng = np.random.default_rng(1)
    for f in (sample_custom, sample_custom_loop):
        batch = f(rng, n_layers, 1000)
        assert validate_batch(batch, n_layers, min_ces=1, max_ces=11).all()
        seg_end = np.asarray(batch.seg_end)
        assert (seg_end <= n_layers).all()
        # every design still decodes to a spec covering all layers
        for i in range(0, 1000, 97):
            spec = decode_design(batch, i, n_layers)
            spec.validate(n_layers)


def test_vectorized_samplers_match_loop_family():
    """Same family envelope as the per-design reference loops: identical
    support for segment counts and total CE counts."""
    rng = np.random.default_rng(2)
    L, n = 30, 4000

    def stats(batch):
        end, pipe, nce, inter = batch.to_numpy()
        prev = np.concatenate([np.zeros((n, 1), end.dtype), end[:, :-1]], 1)
        active = end > prev
        return (np.unique(active.sum(1)),
                np.unique((nce * active).sum(1)))

    for vec, loop in ((sample_custom, sample_custom_loop),
                      (sample_mixed, sample_mixed_loop)):
        sv, cv = stats(vec(rng, L, n))
        sl, cl = stats(loop(rng, L, n))
        assert set(sv) == set(sl)
        assert set(cv) == set(cl)


# ----------------------------------------------------------------- encoding
def _assert_roundtrip(batch: DesignBatch, n_layers: int):
    specs = [decode_design(batch, i, n_layers) for i in range(batch.batch)]
    back = encode_specs(specs, n_layers)
    for a, b in zip(batch.to_numpy(), back.to_numpy()):
        np.testing.assert_array_equal(a, b)


def test_roundtrip_sampled_batches():
    rng = np.random.default_rng(3)
    for L in (6, 21, 52):
        _assert_roundtrip(sample_custom(rng, L, 200), L)
        _assert_roundtrip(sample_mixed(rng, L, 200), L)


def test_roundtrip_mutated_batches():
    """Every mutated/crossed-over row stays canonical: decodes to a valid
    AcceleratorSpec that re-encodes to the same row."""
    rng = np.random.default_rng(4)
    L = 34
    cfg = SearchConfig(min_ces=2, max_ces=11)
    parents = sample_mixed(rng, L, 256)
    kids = make_children(rng, parents, L, cfg, 1024)
    assert validate_batch(kids, L, min_ces=cfg.min_ces,
                          max_ces=cfg.max_ces).all()
    _assert_roundtrip(kids.take(np.arange(0, 1024, 7)), L)
    for i in range(0, 1024, 111):
        decode_design(kids, i, L).validate(L)


# ------------------------------------------------------------------- pareto
def _pareto_seed_reference(points: np.ndarray) -> np.ndarray:
    """The seed implementation's quadratic scan, kept verbatim as oracle."""
    order = np.lexsort(points.T[::-1])
    keep: list[int] = []
    best = np.full(points.shape[1], np.inf)
    for i in order:
        if np.any(points[i] < best - 1e-12) or not keep:
            if not any(np.all(points[j] <= points[i]) and
                       np.any(points[j] < points[i]) for j in keep):
                keep.append(i)
                best = np.minimum(best, points[i])
    return np.asarray(sorted(keep))


def test_pareto_matches_seed_on_random_sets():
    rng = np.random.default_rng(5)
    for n in (1, 2, 17, 400):
        for _ in range(6):
            pts = rng.random((n, 2))
            if n > 4:     # exercise ties and duplicates too
                pts[::5] = np.round(pts[::5], 1)
                pts[3] = pts[1]
            np.testing.assert_array_equal(
                pareto(pts), _pareto_seed_reference(pts))


def test_pareto_nd_is_nondominated():
    rng = np.random.default_rng(6)
    pts = rng.random((300, 3))
    idx = pareto(pts)
    front = pts[idx]
    for p in front:
        assert not ((front <= p).all(1) & (front < p).any(1)).any()
    # every dropped point is weakly dominated by some front point
    rest = np.delete(pts, idx, axis=0)
    for q in rest:
        assert ((front <= q).all(1)).any()


def test_pareto_archive_incremental_matches_batch():
    rng = np.random.default_rng(7)
    pts = rng.random((1200, 2))
    pts[::7] = np.round(pts[::7], 1)
    arch = ParetoArchive(2)
    for lo in range(0, 1200, 100):
        arch.update(pts[lo:lo + 100], np.arange(lo, lo + 100))
    got = np.sort(arch.payload)
    want = pareto(pts)
    np.testing.assert_array_equal(got, want)


# ------------------------------------------------------------------- search
def test_search_metrics_match_scalar_on_searched_designs():
    """Batch metrics of guided-search designs (not just templates) agree
    with the scalar evaluator."""
    net = get_cnn("mobilenetv2")
    dev = get_board("zc706")
    res = search(net, dev, SearchConfig(pop_size=128, budget=512, seed=8))
    pick = np.unique(np.concatenate(
        [res.front_idx[:4], np.arange(0, res.n_evals, res.n_evals // 8)]))
    rtol = {"latency_s": 1e-4, "throughput_ips": 1e-4,
            "buffer_bytes": 1e-4, "access_bytes": 0.04}
    for i in pick:
        spec = decode_design(res.batch, int(i), len(net))
        m = evaluate_design(
            spec, net, dev,
            inter_segment_pipelining=bool(np.asarray(
                res.batch.inter_pipe[int(i)])))
        scalar = {"latency_s": m.latency_s,
                  "throughput_ips": m.throughput_ips,
                  "buffer_bytes": float(m.buffer_bytes),
                  "access_bytes": m.access_bytes}
        for k, tol in rtol.items():
            np.testing.assert_allclose(
                float(res.metrics[k][i]), scalar[k], rtol=tol,
                err_msg=f"design {i} {k}")


def test_explore_search_api():
    net = get_cnn("mobilenetv2")
    dev = get_board("vcu110")
    res = explore(net, dev, n=1024, strategy="search", seed=9, chunk=256,
                  config=SearchConfig(pop_size=256))
    assert res.strategy == "search"
    assert res.n_evals == 1024
    assert len(res.metrics["latency_s"]) == res.n_evals
    assert validate_batch(res.batch, len(net), min_ces=2, max_ces=11).all()
    fp = res.front_points()
    # the reported front is mutually non-dominated and on the sample front
    for p in fp:
        assert not ((fp <= p).all(1) & (fp < p).any(1)).any()
    all_pts = orient(res.metrics, OBJ)
    np.testing.assert_array_equal(np.sort(res.front),
                                  pareto(all_pts))


def test_search_dominates_random_custom_best_latency():
    """Guided search finds designs strictly dominating the best-latency
    design of an equal-budget random sweep of the paper's custom family
    (small-budget version of the Fig. 10 benchmark check)."""
    net = get_cnn("mobilenetv2")
    dev = get_board("vcu110")
    rnd = explore(net, dev, n=16384, seed=7, chunk=4096)
    srch = explore(net, dev, n=16384, strategy="search", seed=3, chunk=4096)
    rp = orient(rnd.metrics, OBJ)
    ref = rp[int(np.argmin(rp[:, 0]))]
    sp = orient(srch.metrics, OBJ)
    assert ((sp <= ref).all(1) & (sp < ref).any(1)).any()


@pytest.mark.slow
def test_search_dominates_random_at_100k_budget():
    """Acceptance check at the paper's full budget: explore(strategy=
    "search") on MobileNetV2 + the default board strictly dominates the
    best random-sample design on (latency, buffer)."""
    net = get_cnn("mobilenetv2")
    dev = get_board()
    rnd = explore(net, dev, n=100_000, seed=7)
    srch = explore(net, dev, n=100_000, strategy="search", seed=3)
    rp = orient(rnd.metrics, OBJ)
    ref = rp[int(np.argmin(rp[:, 0]))]
    sp = orient(srch.metrics, OBJ)
    dom = (sp <= ref).all(1) & (sp < ref).any(1)
    assert dom.any()
