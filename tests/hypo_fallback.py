"""Property-test shim: real ``hypothesis`` when installed, otherwise a tiny
fixed-example fallback so the tier-1 suite collects and runs everywhere.

The fallback implements just the strategy surface these tests use
(integers, floats, lists, sampled_from, composite) as seeded draw
functions, and ``given`` replays each test over a small deterministic
example set — property *smoke* coverage, not full shrinking search.
Install the ``test`` extra (``pip install -e .[test]``) for the real thing.
"""
from __future__ import annotations

try:                                       # pragma: no cover - env dependent
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    import numpy as np

    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 25

    class _Strategy:
        def __init__(self, draw_fn):
            self._draw = draw_fn

        def example(self, rng):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: int(rng.integers(min_value,
                                                          max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: float(rng.uniform(min_value,
                                                           max_value)))

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

        @staticmethod
        def lists(elements, min_size=0, max_size=10, unique=False):
            def draw(rng):
                size = int(rng.integers(min_size, max_size + 1))
                out: list = []
                for _ in range(50 * (size + 1)):
                    if len(out) >= size:
                        break
                    x = elements.example(rng)
                    if unique and x in out:
                        continue
                    out.append(x)
                if len(out) < size:
                    raise ValueError("fallback lists(): cannot draw "
                                     f"{size} unique elements")
                return out
            return _Strategy(draw)

        @staticmethod
        def composite(fn):
            def build(*args, **kwargs):
                def draw_full(rng):
                    return fn(lambda strat: strat.example(rng),
                              *args, **kwargs)
                return _Strategy(draw_full)
            return build

    st = _Strategies()

    def settings(max_examples=None, deadline=None, **_ignored):
        def deco(fn):
            if max_examples is not None:
                fn._hypo_max_examples = max_examples
            return fn
        return deco

    def given(*arg_strats, **kw_strats):
        def deco(fn):
            n = min(getattr(fn, "_hypo_max_examples", _FALLBACK_EXAMPLES),
                    _FALLBACK_EXAMPLES)

            # NOTE: no functools.wraps — pytest must see a zero-arg
            # signature, not the wrapped test's strategy parameters
            def runner():
                for case in range(n):
                    rng = np.random.default_rng(0xC0FFEE + case)
                    args = [s.example(rng) for s in arg_strats]
                    kwargs = {k: s.example(rng) for k, s in kw_strats.items()}
                    fn(*args, **kwargs)
            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            return runner
        return deco


__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
