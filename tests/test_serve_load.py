"""The load generator's trace is a pure function of the seed — the BENCH
point is replayable (docs/serving.md)."""
from __future__ import annotations

import os
import subprocess
import sys

from benchmarks.serve_load import TRACE_BOARDS, TRACE_NETS, make_trace
from repro.core.notation import parse

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_make_trace_deterministic_and_seed_sensitive():
    a, b = make_trace(7, 40), make_trace(7, 40)
    assert a == b
    assert make_trace(8, 40) != a


def test_trace_entries_are_valid_requests():
    trace = make_trace(3, 48)
    assert len(trace) == 48
    t_prev = 0.0
    for e in trace:
        assert e["t"] >= t_prev          # arrival offsets nondecreasing
        t_prev = e["t"]
        assert e["net"] in TRACE_NETS
        assert e["board"] in TRACE_BOARDS
        assert e["priority"] in ("interactive", "batch")
        assert len(e["designs"]) >= 1
        for d in e["designs"]:
            # every design is legal notation at any zoo net depth
            parse(d, n_layers=52)
    assert any(e["priority"] == "batch" for e in trace)
    assert any(e["priority"] == "interactive" for e in trace)


def test_print_trace_cli_is_byte_identical():
    """Two --print-trace subprocess runs at one seed produce identical
    stdout (and differ at another seed) — without importing jax."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(ROOT, "src") + os.pathsep
               + os.environ.get("PYTHONPATH", ""))

    def run(seed: int) -> str:
        out = subprocess.run(
            [sys.executable, "-m", "benchmarks.serve_load",
             "--print-trace", "--seed", str(seed)],
            cwd=ROOT, env=env, capture_output=True, text=True,
            timeout=120)
        assert out.returncode == 0, out.stderr
        return out.stdout

    one, two = run(11), run(11)
    assert one == two
    assert run(12) != one
