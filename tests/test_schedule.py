"""The schedule layer (docs/schedule.md): per-CE temporal-mapping search
under every evaluated design.

The contracts pinned here:

* **never worse** — schedule-refined latency <= the coarse MCCM latency
  for every baseline arch x CNN (candidate 0 IS the coarse mapping, the
  Eq. 2-9 composition is monotone in every per-layer field);
* **bit-parity** — the device candidate plane (jitted jnp) equals the
  pure-Python reference plane (numpy, same statement sequence) field by
  field, including the argmin choice, on every baseline arch x CNN and
  across all boards;
* **budget discipline** (property test) — every scored tiling respects
  its CE's buffer budget, or is the documented minimal-working-set
  clamp;
* **artifact round-trip** — ``ScheduleArtifact`` -> JSON -> artifact is
  bit-identical;
* **compile policy** — warm ``Session.schedule`` across the full zoo
  adds ZERO compiles beyond one per ladder shape.
"""
from __future__ import annotations

import numpy as np
import pytest

from hypo_fallback import given, settings, st
from repro.api import EvalError, ScheduleArtifact, Session, telemetry
from repro.cnn.registry import CNN_NAMES, get_cnn
from repro.core.batch_eval import bucket_max_L
from repro.core.dse.encoding import encode_specs
from repro.fpga.archs import ARCH_NAMES, make_arch
from repro.fpga.boards import BOARD_NAMES, get_board
from repro.kernels.schedule_score import (CAND_DB, CAND_FRAC, CAND_ORDER,
                                          FRACS, NCAND, ORDER_NAMES,
                                          decode_candidate)
from repro.schedule import build_artifact, schedule_specs
from repro.schedule.search import device_plane, reference_plane

BOARD = "zc706"
SPEC = "{L1-Last:CE1-CE4}"

#: the baseline design sweep of one CNN: every arch family at a range
#: of CE counts (the tab4 grid, shortened for test runtime)
N_CES = range(2, 12)


@pytest.fixture(scope="module")
def ses():
    s = Session(get_board(BOARD))
    yield s
    s.close()


def _designs(net):
    return [make_arch(a, net, n) for a in ARCH_NAMES for n in N_CES]


def _sweep(ses, net, dev=None):
    dev = get_board(BOARD) if dev is None else dev
    return schedule_specs(_designs(net), net, ses.device_tables(dev),
                          tables=ses.tables(net))


# --------------------------------------------------------------------------
# candidate space sanity
# --------------------------------------------------------------------------
def test_candidate_space_shape():
    assert NCAND == 1 + (len(ORDER_NAMES) - 1) * len(FRACS) * 2 == 19
    assert CAND_ORDER.shape == CAND_FRAC.shape == CAND_DB.shape == (NCAND,)
    c0 = decode_candidate(0)
    assert c0 == {"order": "ideal", "tile_frac": 1.0,
                  "double_buffer": True}
    seen = {tuple(decode_candidate(i).items()) for i in range(NCAND)}
    assert len(seen) == NCAND            # no duplicate mappings


# --------------------------------------------------------------------------
# never worse than coarse + genuine strict refinement
# --------------------------------------------------------------------------
def test_refined_never_worse_on_every_arch_and_cnn(ses):
    """The acceptance criterion: schedule-refined latency <= coarse MCCM
    latency for EVERY baseline arch x CNN — and across the whole sweep
    at least one design strictly improves (the search is not a no-op)."""
    strict = 0
    for name in CNN_NAMES:
        net = get_cnn(name)
        out = _sweep(ses, net)
        lat, coarse = out["ref_latency_s"], out["coarse_latency_s"]
        assert np.isfinite(lat).all() and np.isfinite(coarse).all()
        worse = lat > coarse
        assert not worse.any(), \
            f"{name}: {int(worse.sum())} design(s) refined WORSE"
        strict += int((lat < coarse).sum())
    assert strict >= 1, "no design anywhere strictly improved"


def test_refined_equals_coarse_bitwise_when_nothing_wins(ses):
    """Where no candidate beats the ideal mapping (choice stays 0 on
    every valid layer), refined metrics are BIT-IDENTICAL to coarse —
    candidate 0 carries the coarse cost verbatim and argmin tie-breaks
    to the first index."""
    net = get_cnn("vgg16")
    out = _sweep(ses, net)
    choice, valid = out["choice"], out["valid_l"].astype(bool)
    untouched = ~np.any((choice != 0) & valid, axis=1)
    assert untouched.any()               # the regime exists in the sweep
    for k in ("latency_s", "throughput_ips", "access_bytes",
              "buffer_bytes"):
        np.testing.assert_array_equal(out[f"ref_{k}"][untouched],
                                      out[f"coarse_{k}"][untouched])


# --------------------------------------------------------------------------
# bit-parity: device plane == pure-Python reference plane
# --------------------------------------------------------------------------
def _parity_one(ses, net, board_name, spec):
    t = ses.tables(net)
    dev = ses.device_tables(get_board(board_name))
    design = encode_specs([spec], len(net))
    dp = device_plane(design, t, dev)
    rp, rchoice, _st = reference_plane(design, t, dev)
    np.testing.assert_array_equal(dp["choice"], rchoice)
    for k, v in rp.items():
        np.testing.assert_array_equal(dp[k], np.asarray(v),
                                      err_msg=f"{board_name}/{net.name} "
                                              f"field {k}")


def test_device_plane_matches_reference_every_arch_and_cnn(ses):
    """Every baseline arch x CNN on the reference board: the jitted
    device plane and the numpy reference agree bitwise on every field
    and on the argmin choice."""
    for name in CNN_NAMES:
        net = get_cnn(name)
        for arch in ARCH_NAMES:
            _parity_one(ses, net, BOARD, make_arch(arch, net, 4))


def test_device_plane_matches_reference_every_board(ses):
    net = get_cnn("resnet50")
    for board in BOARD_NAMES:
        for arch in ARCH_NAMES:
            _parity_one(ses, net, board, make_arch(arch, net, 6))


# --------------------------------------------------------------------------
# budget discipline (property test)
# --------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(arch=st.sampled_from(ARCH_NAMES),
       n=st.integers(min_value=2, max_value=11),
       board=st.sampled_from(BOARD_NAMES),
       net_name=st.sampled_from(CNN_NAMES))
def test_every_tiling_respects_the_buffer_budget(arch, n, board, net_name):
    """For EVERY candidate of every layer: the chosen tile plus its
    companion working set fits the CE's buffer budget, OR the tile is
    the documented minimal-working-set clamp (tile == floor, mirroring
    the coarse model's own buffer floors).  Collapsed rows (ideal /
    residency-chain / fully-resident) report zeros and pass trivially."""
    net = get_cnn(net_name)
    ses = _budget_session()
    t = ses.tables(net)
    dev = ses.device_tables(get_board(board))
    design = encode_specs([make_arch(arch, net, n)], len(net))
    plane, _choice, _st = reference_plane(design, t, dev)
    tile = plane["tile_bytes"]
    comp = plane["companion_bytes"]
    floor = plane["floor_bytes"]
    budget = plane["budget_bytes"]
    eps = 1e-3 * np.maximum(budget, 1.0)
    fits = tile + comp <= budget + eps
    clamped = tile <= floor + eps
    bad = ~(fits | clamped)
    assert not bad.any(), (
        f"{net_name}/{board}/{arch}-{n}: {int(bad.sum())} tiling(s) "
        "overflow their buffer budget without being the floor clamp")


_BUDGET_SES = None


def _budget_session() -> Session:
    """One shared session for the property test (tables memoized across
    examples — the draw space revisits the same nets/boards)."""
    global _BUDGET_SES
    if _BUDGET_SES is None:
        _BUDGET_SES = Session(get_board(BOARD))
    return _BUDGET_SES


# --------------------------------------------------------------------------
# the artifact
# --------------------------------------------------------------------------
def test_artifact_json_round_trip_bit_identical(ses):
    net = get_cnn("mobilenetv2")
    for arch in ARCH_NAMES:
        art = ses.schedule(make_arch(arch, net, 5), net)
        rt = ScheduleArtifact.from_json(art.to_json())
        assert rt == art                 # dataclass equality: every float
        rt2 = ScheduleArtifact.from_json(art.to_json(indent=2))
        assert rt2 == art


def test_artifact_contents_are_consistent(ses):
    net = get_cnn("resnet50")
    art = ses.schedule(make_arch("hybrid", net, 6), net)
    assert art.net == net.name and art.board == BOARD
    assert art.latency_s <= art.coarse_latency_s
    assert art.n_candidates == len(art.layers) * NCAND
    assert art.meta["n_layers"] == len(net)
    covered = sorted(l.layer for l in art.layers)
    assert covered == sorted(set(covered))      # each layer at most once
    for ls in art.layers:
        assert ls.order in ORDER_NAMES
        assert ls.latency_cyc <= ls.coarse_cyc
        assert 0.0 <= ls.phi <= 1.0
    plan_layers = sorted(l for p in art.ce_plans for l in p.layers)
    assert plan_layers == covered               # plans partition layers
    for seg in art.segments:
        assert seg.refined_cyc <= seg.coarse_cyc


def test_build_artifact_rejects_out_of_range_index(ses):
    net = get_cnn("mobilenetv2")
    out = _sweep(ses, net)
    with pytest.raises(IndexError):
        build_artifact(out, 10_000, net=net, board_name=BOARD,
                       design_repr="x", wordbytes=1)


# --------------------------------------------------------------------------
# Session surface: schedule / explain / explore
# --------------------------------------------------------------------------
def test_session_schedule_validates_input(ses):
    net = get_cnn("mobilenetv2")
    with pytest.raises(EvalError) as ei:
        ses.schedule([SPEC, SPEC], net)          # batches not allowed
    assert ei.value.code == EvalError.INVALID_INPUT
    with pytest.raises(EvalError) as ei:
        ses.schedule("{not notation", net)
    assert ei.value.code == EvalError.INVALID_INPUT


def test_explain_refine_schedule_attaches_section(ses):
    net = get_cnn("mobilenetv2")
    plain = ses.explain(SPEC, net)
    assert "schedule" not in plain
    rep = ses.explain(SPEC, net, refine="schedule")
    sched = rep["schedule"]
    assert sched["latency_s"] <= sched["coarse_latency_s"]
    assert 0.0 <= sched["saving_frac"] <= 1.0
    assert len(sched["segments"]) >= 1
    for s in sched["segments"]:
        assert s["refined_cyc"] <= s["coarse_cyc"]
    # the coarse attribution is untouched by the refinement
    for k in ("segments", "ces", "bottleneck", "summary"):
        assert rep[k] == plain[k]
    with pytest.raises(EvalError):
        ses.explain(SPEC, net, refine="warp")


def test_explore_refine_schedule_rescores_front(ses):
    net = get_cnn("mobilenetv2")
    res = ses.explore(net, n=256, strategy="random", seed=3,
                      refine="schedule")
    base = ses.explore(net, n=256, strategy="random", seed=3)
    assert base.refined is None
    # the sweep itself is untouched by the refinement
    np.testing.assert_array_equal(res.front, base.front)
    np.testing.assert_array_equal(res.metrics["latency_s"],
                                  base.metrics["latency_s"])
    r = res.refined
    nf = res.front.size
    assert {k: v.shape for k, v in r.items()} == \
        {k: (nf,) for k in r}
    assert (r["latency_s"] <= r["coarse_latency_s"]).all()
    # refined equals the scalar schedule path for each front member
    np.testing.assert_array_equal(
        r["coarse_latency_s"], base.metrics["latency_s"][base.front])
    with pytest.raises(EvalError):
        ses.explore(net, n=4, refine="warp")


def test_format_report_renders_schedule_section(ses):
    from repro.api import format_report

    net = get_cnn("mobilenetv2")
    rep = ses.explain(SPEC, net, refine="schedule")
    text = format_report(rep)
    assert "schedule refinement" in text


# --------------------------------------------------------------------------
# compile policy: zero new compiles on warm calls
# --------------------------------------------------------------------------
def test_warm_schedule_across_zoo_adds_zero_compiles():
    """Cold pass over the full zoo compiles at most one schedule program
    per ladder shape; a second pass with DIFFERENT designs (artifact
    memo misses, so the device search runs again) adds ZERO compiles."""
    ses = Session(get_board(BOARD))
    nets = [get_cnn(n) for n in CNN_NAMES]
    for net in nets:                         # cold pass
        ses.schedule(make_arch("segmented", net, 4), net)
    counts = ses.compile_stats()
    ladder_shapes = len({bucket_max_L(len(n)) for n in nets})
    assert 1 <= counts["schedule_batch"] <= ladder_shapes
    total = counts["total"]
    builds = ses.stats.schedule_builds
    for net in nets:                         # warm pass, new designs
        ses.schedule(make_arch("hybrid", net, 3), net)
    assert ses.stats.schedule_builds == builds + len(nets)  # memo missed
    assert ses.compile_stats()["total"] == total            # zero compiles
    ses.close()


def test_schedule_telemetry_counters():
    telemetry.disable()
    telemetry.reset()
    telemetry.enable()
    try:
        ses = Session(get_board(BOARD))
        net = get_cnn("mobilenetv2")
        art = ses.schedule(SPEC, net)
        snap = telemetry.snapshot()
        assert snap["counters"]["schedule.searches"] == 1
        assert snap["counters"]["schedule.candidates"] == art.n_candidates
        ses.schedule(SPEC, net)              # memo hit: no new search
        assert telemetry.snapshot()["counters"]["schedule.searches"] == 1
        ses.close()
    finally:
        telemetry.disable()
        telemetry.reset()
