"""Builder heuristics: resource conservation + proportionality invariants."""
from __future__ import annotations

import pytest
from hypo_fallback import given, settings, st

from repro.cnn.registry import get_cnn
from repro.core.builder import _largest_remainder, build
from repro.core.evaluator import evaluate_design
from repro.fpga.archs import make_arch
from repro.fpga.boards import get_board


@given(st.lists(st.floats(0.0, 1e9), min_size=1, max_size=12),
       st.integers(8, 4000))
@settings(max_examples=80, deadline=None)
def test_largest_remainder_conserves(shares, total):
    out = _largest_remainder(shares, total, floor=1)
    assert sum(out) == max(total, len(shares))
    assert all(x >= 1 for x in out)


@pytest.mark.parametrize("arch", ["segmented", "segmented_rr", "hybrid"])
@pytest.mark.parametrize("n", [2, 5, 11])
def test_build_conserves_resources(arch, n):
    net = get_cnn("resnet50")
    dev = get_board("vcu108")
    acc = build(make_arch(arch, net, n), net, dev)
    pes = sum(ce.pes for seg in acc.segments for ce in seg.ces)
    assert pes == dev.pes
    bufs = sum(ce.buffer_bytes for seg in acc.segments for ce in seg.ces)
    bufs += sum(2 * sz for sz, on in zip(acc.inter_seg_buffer_bytes,
                                         acc.inter_seg_onchip) if on)
    assert bufs <= dev.on_chip_bytes


def test_pe_distribution_proportional_to_macs():
    net = get_cnn("resnet50")
    dev = get_board("zcu102")
    acc = build(make_arch("segmented", net, 4), net, dev)
    macs = [sum(l.macs for l in net.slice(s.spec.layer_lo, s.spec.layer_hi))
            for s in acc.segments]
    pes = [s.ces[0].pes for s in acc.segments]
    total_m, total_p = sum(macs), sum(pes)
    for m, p in zip(macs, pes):
        assert p / total_p == pytest.approx(m / total_m, abs=0.02)


def test_more_ces_more_throughput_rr():
    """SegmentedRR's point: more pipelined CEs -> >= throughput (ResNet50,
    big board, weights resident)."""
    net = get_cnn("resnet50")
    dev = get_board("zcu102")
    tps = [evaluate_design(make_arch("segmented_rr", net, n), net, dev)
           .throughput_ips for n in (2, 4, 8)]
    assert tps[1] >= tps[0] * 0.9 and tps[2] >= tps[0] * 0.9


def test_evaluate_design_metrics_sane():
    net = get_cnn("mobilenetv2")
    dev = get_board("zc706")
    m = evaluate_design("{L1-Last:CE1-CE4}", net, dev)
    assert m.latency_s > 0 and m.throughput_ips > 0
    assert m.buffer_bytes > 0 and m.access_bytes > 0
    assert m.throughput_ips >= 1.0 / m.latency_s - 1e-9  # pipe >= serial
