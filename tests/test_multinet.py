"""The multinet co-scheduling subsystem: M=1 reduction to the single-model
evaluator, partition-repair guarantees, the extended one-compile claim,
hybrid deployments reducing bit-identically to both pure modes, the
SLO-driven search, and joint DSE dominating the equal-split baseline."""
from __future__ import annotations

import numpy as np
import pytest

from repro.cnn.registry import CNN_NAMES, get_cnn
from repro.core.batch_eval import (bucket_max_L, evaluate_batch, make_tables,
                                   make_device_tables, shared_max_L)
from repro.core.dse import sample_assign, stack_designs
from repro.core.dse.pareto import hypervolume_2d
from repro.core.dse.samplers import sample_mixed
from repro.core.dse.search import orient
from repro.core.multinet import (DEFAULT_MAX_M, MultinetSearchConfig,
                                 PartitionBatch, equal_shares, joint_evaluate,
                                 joint_explore, make_multi_tables,
                                 repair_partition_jax, sample_shares,
                                 slo_attainment_dist, validate_partition)
from repro.fpga.archs import ARCH_NAMES, make_arch
from repro.fpga.boards import BOARD_NAMES, get_board

from hypo_fallback import given, settings, st


# ------------------------------------------------------------- M=1 identity
@pytest.mark.parametrize("cnn", CNN_NAMES)
def test_m1_spatial_bit_identical_to_single_model(cnn):
    """A single-model spatial deployment (full budget) reproduces the
    single-model evaluator bit for bit, on every baseline arch × CNN."""
    net = get_cnn(cnn)
    dev = get_board("vcu108")
    specs = [make_arch(a, net, n) for a in ARCH_NAMES for n in (2, 9)]
    from repro.core.dse.encoding import encode_specs
    db = encode_specs(specs, len(net))
    single = evaluate_batch(db, make_tables(net), dev, backend="ref")
    mt = make_multi_tables([net])
    out = joint_evaluate(stack_designs([db], DEFAULT_MAX_M), mt, dev)
    for k in ("latency_s", "throughput_ips", "buffer_bytes", "access_bytes",
              "utilization", "n_ces"):
        np.testing.assert_array_equal(
            np.asarray(single[k]), np.asarray(out[f"per_model_{k}"])[:, 0],
            err_msg=f"{cnn} {k}")
    # system metrics reduce to the single model's metrics
    np.testing.assert_array_equal(np.asarray(out["worst_latency_s"]),
                                  np.asarray(single["latency_s"]))
    np.testing.assert_array_equal(np.asarray(out["agg_throughput_ips"]),
                                  np.asarray(single["throughput_ips"]))


# -------------------------------------------------------- partition repair
@settings(max_examples=40, deadline=None)
@given(m=st.integers(1, DEFAULT_MAX_M),
       board=st.sampled_from(BOARD_NAMES),
       seed=st.integers(0, 10_000))
def test_partition_repair_sums_to_budget_and_respects_floors(m, board, seed):
    """Property: repaired partitions always sum to the board budget (BRAM
    in 1-KiB granules for M >= 2) and never starve a model below its
    floor — for arbitrary raw shares, including degenerate ones."""
    rng = np.random.default_rng(seed)
    dev = get_board(board)
    devt = make_device_tables(dev)
    model_valid = np.zeros(DEFAULT_MAX_M, np.float32)
    model_valid[:m] = 1.0
    B = 16
    raw = [rng.gamma(0.3, 1.0, size=(B, DEFAULT_MAX_M)).astype(np.float32)
           for _ in range(3)]
    raw[0][0] = 0.0                      # all-zero row -> equal fallback
    raw[1][1, :1] = 1e9                  # extreme skew
    part = repair_partition_jax(raw[0], raw[1], raw[2], devt,
                                model_valid)
    part = PartitionBatch(*[np.asarray(x) for x in
                            (part.pes, part.buf, part.bw)])
    assert validate_partition(part, dev, model_valid).all()
    # pes splits are integers
    assert (np.asarray(part.pes) == np.round(np.asarray(part.pes))).all()


def test_equal_shares_round_trip():
    dev = get_board("zc706")
    devt = make_device_tables(dev)
    model_valid = np.array([1, 1, 0, 0], np.float32)
    eq = equal_shares(4, DEFAULT_MAX_M, 2)
    part = repair_partition_jax(eq, eq, eq, devt, model_valid)
    pes = np.asarray(part.pes)
    assert pes[:, :2].sum(-1) == pytest.approx(dev.pes)
    assert abs(pes[0, 0] - pes[0, 1]) <= 1       # near-equal integers
    assert (pes[:, 2:] == 0).all()


# ----------------------------------------------------- one-compile at M<=3
def test_joint_eval_single_compile_across_m_boards_models():
    """The extended recompile-free claim: ONE jit compile serves every
    (model set × board × split) joint evaluation at M ∈ {1, 2, 3}."""
    import jax

    from repro.core.multinet import joint_eval as je

    jax.clear_caches()
    assert je._joint_spatial_jit._cache_size() == 0
    rng = np.random.default_rng(11)
    combos = [(("mobilenetv2",), "zc706"),
              (("resnet50", "mobilenetv2"), "vcu110"),
              (("resnet50", "mobilenetv2", "densenet121"), "zcu102"),
              (("vgg16", "resnet101"), "vcu108")]
    B = 32
    for names, board in combos:
        nets = [get_cnn(n) for n in names]
        mt = make_multi_tables(nets)
        md = stack_designs([sample_mixed(rng, len(n), B) for n in nets],
                           DEFAULT_MAX_M)
        sh = [sample_shares(rng, B, DEFAULT_MAX_M, len(nets))
              for _ in range(3)]
        out = joint_evaluate(md, mt, get_board(board), pes_shares=sh[0],
                             buf_shares=sh[1], bw_shares=sh[2])
        assert np.isfinite(np.asarray(out["worst_latency_s"])).all()
    assert je._joint_spatial_jit._cache_size() == 1


def test_shared_max_l_bucketing():
    """All zoo nets share the base bucket; oversized nets move the whole
    deployment to the next step instead of forking compiles."""
    assert bucket_max_L(52) == bucket_max_L(155) == 160
    assert bucket_max_L(161) == 192
    assert shared_max_L([53, 52]) == 160
    assert shared_max_L([53, 170]) == 192
    mt = make_multi_tables([get_cnn("resnet152"), get_cnn("mobilenetv2")])
    assert mt.tables.F.shape == (DEFAULT_MAX_M, 160)


# ------------------------------------------------------------ temporal mode
def test_temporal_metrics_account_for_sharing_and_switching():
    """Round-robin time shares sum to 1; each model's effective throughput
    is below its time-share of the full-board throughput (weight reload
    charges); latency exceeds the full-board latency."""
    rng = np.random.default_rng(5)
    nets = [get_cnn("resnet50"), get_cnn("mobilenetv2")]
    dev = get_board("zc706")
    mt = make_multi_tables(nets)
    B = 16
    dbs = [sample_mixed(rng, len(n), B) for n in nets]
    md = stack_designs(dbs, DEFAULT_MAX_M)
    tsh = sample_shares(rng, B, DEFAULT_MAX_M, 2)
    out = joint_evaluate(md, mt, dev, mode="temporal", time_shares=tsh)
    shares = np.asarray(out["time_share"])
    np.testing.assert_allclose(shares[:, :2].sum(-1), 1.0, rtol=1e-5)
    full = [evaluate_batch(db, make_tables(net), dev)
            for db, net in zip(dbs, nets)]
    for i in range(2):
        tp = np.asarray(out["per_model_throughput_ips"])[:, i]
        lat = np.asarray(out["per_model_latency_s"])[:, i]
        assert (tp <= np.asarray(full[i]["throughput_ips"])
                * shares[:, i] + 1e-6).all()
        assert (lat > np.asarray(full[i]["latency_s"])).all()


# ------------------------------------------------- hybrid mode reductions
def _hybrid_fixture(seed=0, B=12):
    rng = np.random.default_rng(seed)
    nets = [get_cnn("resnet50"), get_cnn("mobilenetv2")]
    dev = get_board("zc706")
    mt = make_multi_tables(nets, slo_s=[0.05, 0.01])
    md = stack_designs([sample_mixed(rng, len(n), B) for n in nets],
                       DEFAULT_MAX_M)
    sh = [sample_shares(rng, B, DEFAULT_MAX_M, 2) for _ in range(3)]
    tsh = sample_shares(rng, B, DEFAULT_MAX_M, 2)
    return nets, dev, mt, md, sh, tsh


def test_hybrid_all_spatial_bit_identical_to_spatial_mode():
    """A hybrid deployment whose models all own dedicated slices is the
    spatial mode, bit for bit — every metric, split and per-model plane."""
    nets, dev, mt, md, sh, tsh = _hybrid_fixture()
    B = md.batch
    out_s = joint_evaluate(md, mt, dev, pes_shares=sh[0], buf_shares=sh[1],
                           bw_shares=sh[2])
    out_h = joint_evaluate(md, mt, dev, mode="hybrid",
                           assign=np.zeros((B, DEFAULT_MAX_M), np.float32),
                           pes_shares=sh[0], buf_shares=sh[1],
                           bw_shares=sh[2], time_shares=tsh)
    for k in out_s:
        np.testing.assert_array_equal(np.asarray(out_s[k]),
                                      np.asarray(out_h[k]), err_msg=k)
    assert (np.asarray(out_h["assign"]) == 0).all()
    assert (np.asarray(out_h["round_period_s"]) == 0).all()


def test_hybrid_all_shared_bit_identical_to_temporal_mode():
    """A hybrid deployment whose models all share the time-multiplexed
    slice is the temporal mode, bit for bit: the lone slice takes the
    board verbatim and the per-slice RR reduces to the global RR —
    including a nonzero partial-reconfiguration charge."""
    nets, dev, mt, md, sh, tsh = _hybrid_fixture(seed=2)
    B, m = md.batch, len(nets)
    assign = np.zeros((B, DEFAULT_MAX_M), np.float32)
    assign[:, :m] = 1.0
    out_t = joint_evaluate(md, mt, dev, mode="temporal", time_shares=tsh,
                           reconfig_s=0.004)
    out_h = joint_evaluate(md, mt, dev, mode="hybrid", assign=assign,
                           pes_shares=sh[0], buf_shares=sh[1],
                           bw_shares=sh[2], time_shares=tsh,
                           reconfig_s=0.004)
    for k in out_t:
        a, b = np.asarray(out_t[k]), np.asarray(out_h[k])
        if a.ndim == 2:     # per-model planes: padded columns are
            a, b = a[:, :m], b[:, :m]   # documented to differ
        np.testing.assert_array_equal(a, b, err_msg=k)
    # the shared slice IS the whole board
    assert (np.asarray(out_h["pes_split"])[:, :m]
            == np.float32(dev.pes)).all()


def test_hybrid_m1_reduces_to_single_model_and_temporal():
    """An M=1 hybrid deployment: a dedicated model reproduces the
    single-model evaluator bit for bit; a shared-alone model reproduces
    the M=1 temporal mode (it still pays its per-round weight reload)."""
    net = get_cnn("xception")
    dev = get_board("vcu108")
    specs = [make_arch(a, net, n) for a in ARCH_NAMES for n in (2, 9)]
    from repro.core.dse.encoding import encode_specs
    db = encode_specs(specs, len(net))
    B = db.batch
    single = evaluate_batch(db, make_tables(net), dev, backend="ref")
    mt = make_multi_tables([net])
    md = stack_designs([db], DEFAULT_MAX_M)
    out = joint_evaluate(md, mt, dev, mode="hybrid",
                         assign=np.zeros((B, DEFAULT_MAX_M), np.float32))
    for k in ("latency_s", "throughput_ips", "buffer_bytes",
              "access_bytes", "utilization", "n_ces"):
        np.testing.assert_array_equal(
            np.asarray(single[k]), np.asarray(out[f"per_model_{k}"])[:, 0],
            err_msg=k)
    assign1 = np.zeros((B, DEFAULT_MAX_M), np.float32)
    assign1[:, 0] = 1.0
    tsh = np.ones((B, DEFAULT_MAX_M), np.float32)
    out_t = joint_evaluate(md, mt, dev, mode="temporal", time_shares=tsh)
    out_h = joint_evaluate(md, mt, dev, mode="hybrid", assign=assign1,
                           time_shares=tsh)
    np.testing.assert_array_equal(
        np.asarray(out_t["per_model_latency_s"])[:, 0],
        np.asarray(out_h["per_model_latency_s"])[:, 0])
    np.testing.assert_array_equal(np.asarray(out_t["round_period_s"]),
                                  np.asarray(out_h["round_period_s"]))


def test_hybrid_mixed_assignment_charges_only_shared_models():
    """In a mixed deployment the dedicated model's metrics equal the pure
    spatial evaluation on the same raw shares (a lone shared member pools
    exactly its own share, so the slice split coincides), while the
    shared member pays its per-round weight reload: strictly higher
    latency and strictly lower throughput on the same slice."""
    nets, dev, mt, md, sh, tsh = _hybrid_fixture(seed=5)
    B = md.batch
    assign = np.zeros((B, DEFAULT_MAX_M), np.float32)
    assign[:, 1] = 1.0                  # mobilenetv2 shared, resnet50 not
    out = joint_evaluate(md, mt, dev, mode="hybrid", assign=assign,
                         pes_shares=sh[0], buf_shares=sh[1],
                         bw_shares=sh[2], time_shares=tsh)
    out_s = joint_evaluate(md, mt, dev, pes_shares=sh[0],
                           buf_shares=sh[1], bw_shares=sh[2])
    np.testing.assert_array_equal(np.asarray(out["pes_split"]),
                                  np.asarray(out_s["pes_split"]))
    lat_h = np.asarray(out["per_model_latency_s"])
    lat_s = np.asarray(out_s["per_model_latency_s"])
    np.testing.assert_array_equal(lat_h[:, 0], lat_s[:, 0])
    assert (lat_h[:, 1] > lat_s[:, 1]).all()
    tp_h = np.asarray(out["per_model_throughput_ips"])
    tp_s = np.asarray(out_s["per_model_throughput_ips"])
    np.testing.assert_array_equal(tp_h[:, 0], tp_s[:, 0])
    assert (tp_h[:, 1] < tp_s[:, 1]).all()


def test_joint_hybrid_single_compile_across_assignments():
    """The one-compile claim for hybrid deployments: assignments are
    traced data — all-spatial, all-shared and mixed assignments at M ∈
    {1, 2, 3} on four boards run through ONE compiled program."""
    import jax

    from repro.core.multinet import joint_eval as je

    jax.clear_caches()
    assert je._joint_hybrid_jit._cache_size() == 0
    rng = np.random.default_rng(17)
    combos = [(("mobilenetv2",), "zc706", "spatial"),
              (("resnet50", "mobilenetv2"), "vcu110", "shared"),
              (("resnet50", "mobilenetv2", "densenet121"), "zcu102",
               "mixed"),
              (("vgg16", "resnet101"), "vcu108", "mixed")]
    B = 32
    for names, board, kind in combos:
        nets = [get_cnn(n) for n in names]
        m = len(nets)
        mt = make_multi_tables(nets)
        md = stack_designs([sample_mixed(rng, len(n), B) for n in nets],
                           DEFAULT_MAX_M)
        sh = [sample_shares(rng, B, DEFAULT_MAX_M, m) for _ in range(4)]
        assign = np.zeros((B, DEFAULT_MAX_M), np.float32)
        if kind == "shared":
            assign[:, :m] = 1.0
        elif kind == "mixed":
            assign = sample_assign(rng, B, DEFAULT_MAX_M, m)
        out = joint_evaluate(md, mt, get_board(board), mode="hybrid",
                             assign=assign, pes_shares=sh[0],
                             buf_shares=sh[1], bw_shares=sh[2],
                             time_shares=sh[3])
        assert np.isfinite(np.asarray(out["worst_latency_s"])).all()
    assert je._joint_hybrid_jit._cache_size() == 1


# --------------------------------------------- SLO deadline distributions
def test_slo_attainment_dist_grading():
    """The graded metric: 1 with no SLOs, 0 when every deadline misses,
    monotone in latency, and request-weighted across models."""
    nets = [get_cnn("resnet50"), get_cnn("mobilenetv2")]
    mt_free = make_multi_tables(nets)                  # slo = inf
    lat = np.array([[0.5, 0.5], [1e9, 1e9]], np.float32)
    np.testing.assert_allclose(slo_attainment_dist(lat, mt_free), 1.0)
    mt = make_multi_tables(nets, slo_s=[0.010, 0.010],
                           weights=[3.0, 1.0])
    att = slo_attainment_dist(
        np.array([[1e9, 1e9],      # nothing met
                  [1e-6, 1e9],     # model 0 fully met (weight 3/4)
                  [1e-6, 1e-6],    # everything met
                  [0.009, 1e9]],   # model 0 partially met
                 np.float32), mt)
    assert att[0] == 0.0 and att[2] == 1.0
    np.testing.assert_allclose(att[1], 0.75)
    assert 0.0 < att[3] < 0.75
    # tighter latency never lowers attainment
    lat_grid = np.linspace(1e-4, 0.05, 32, dtype=np.float32)
    a = slo_attainment_dist(np.stack([lat_grid, lat_grid], 1), mt)
    assert (np.diff(a) <= 1e-12).all()


def test_make_multi_tables_validation_and_broadcast():
    nets = [get_cnn("resnet50"), get_cnn("mobilenetv2")]
    with pytest.raises(ValueError, match="non-negative"):
        make_multi_tables(nets, weights=[1.0, -2.0])
    with pytest.raises(ValueError, match="all zero"):
        make_multi_tables(nets, weights=[0.0, 0.0])
    with pytest.raises(ValueError, match="finite"):
        make_multi_tables(nets, weights=[np.inf, 1.0])
    with pytest.raises(ValueError, match="weights"):
        make_multi_tables(nets, weights=[1.0, 1.0, 1.0])
    with pytest.raises(ValueError, match="slo_s"):
        make_multi_tables(nets, slo_s=[0.1])
    with pytest.raises(ValueError, match="positive"):
        make_multi_tables(nets, slo_s=[-0.1, 0.1])
    # scalars broadcast; normalized weights are exposed for reporting
    mt = make_multi_tables(nets, weights=5.0, slo_s=0.25)
    np.testing.assert_allclose(mt.normalized_weights, [0.5, 0.5])
    assert np.asarray(mt.slo_s)[:2].tolist() == [0.25, 0.25]
    mt2 = make_multi_tables(nets, weights=[1.0, 3.0])
    np.testing.assert_allclose(mt2.normalized_weights, [0.25, 0.75])
    np.testing.assert_allclose(np.asarray(mt2.weights).sum(), 1.0,
                               rtol=1e-6)
    # a zero-weight (trafficless) model is allowed and excluded from the
    # weighted-rate metrics rather than blowing them up
    mt3 = make_multi_tables(nets, weights=[1.0, 0.0])
    rng = np.random.default_rng(0)
    md = stack_designs([sample_mixed(rng, len(n), 4) for n in nets],
                       DEFAULT_MAX_M)
    out = joint_evaluate(md, mt3, get_board("zc706"))
    assert np.isfinite(np.asarray(out["fairness"])).all()
    assert np.isfinite(np.asarray(out["min_model_throughput_ips"])).all()


def test_hybrid_slo_search_smoke():
    """objective='slo' on the hybrid space: resolves to the SLO
    objectives, stores the assignment genome, and archives the graded
    attainment metric for every deployment."""
    nets = [get_cnn("resnet50"), get_cnn("mobilenetv2")]
    dev = get_board("zc706")
    cfg = MultinetSearchConfig(pop_size=64, seed=11, objective="slo",
                               slo_s=(0.08, 0.02))
    res = joint_explore(nets, dev, 128, strategy="hybrid", config=cfg)
    assert res.objectives == ("slo_attainment_dist", "agg_throughput_ips")
    assert res.metrics["slo_attainment_dist"].shape == (128,)
    assert ((0.0 <= res.metrics["slo_attainment_dist"])
            & (res.metrics["slo_attainment_dist"] <= 1.0)).all()
    assert res.shares["assign"].shape == (128, DEFAULT_MAX_M)
    assert res.metrics["assign"].shape == (128, DEFAULT_MAX_M)
    assert len(res.front) >= 1
    # objective='slo' without SLOs anywhere is a config error
    with pytest.raises(ValueError, match="slo"):
        joint_explore(nets, dev, 64, strategy="hybrid",
                      config=MultinetSearchConfig(pop_size=64,
                                                  objective="slo"))


# ------------------------------------------------------------- joint DSE
def test_joint_search_dominates_equal_split_baseline():
    """Acceptance: joint DSE on resnet50+mobilenetv2/zc706 yields a front
    that dominates the equal-split baseline at the SAME budget (same
    operators and seed; only the split is free vs frozen)."""
    nets = [get_cnn("resnet50"), get_cnn("mobilenetv2")]
    dev = get_board("zc706")
    budget, cfg = 1536, MultinetSearchConfig(pop_size=256, seed=3)
    srch = joint_explore(nets, dev, budget, strategy="search", config=cfg)
    eq = joint_explore(nets, dev, budget, strategy="equal_split",
                       config=cfg)
    sp, ep = srch.front_points(), eq.front_points()
    allp = np.concatenate([sp, ep])
    # pad outward (oriented coords are negative on the throughput axis)
    ref = allp.max(0) + 0.05 * np.maximum(np.ptp(allp, 0), 1e-9)
    assert hypervolume_2d(sp, ref) > hypervolume_2d(ep, ref)
    # every equal-split front point is weakly dominated by the searched
    # front, at least one strictly
    weak = np.array([((sp <= q).all(1)).any() for q in ep])
    strict = np.array([((sp <= q).all(1) & (sp < q).any(1)).any()
                       for q in ep])
    assert weak.all() and strict.any()


def test_joint_explore_random_and_result_shape():
    nets = [get_cnn("mobilenetv2"), get_cnn("xception")]
    dev = get_board("vcu110")
    res = joint_explore(nets, dev, 96, strategy="random", seed=1, chunk=64)
    assert res.n_evals == 96
    assert res.metrics["worst_latency_s"].shape == (96,)
    assert res.metrics["pes_split"].shape == (96, DEFAULT_MAX_M)
    assert len(res.front) >= 1
    pts = orient(res.metrics, res.objectives)
    fp = res.front_points()
    for p in fp:                     # front is mutually non-dominated
        assert not ((fp <= p).all(1) & (fp < p).any(1)).any()
    assert np.isfinite(pts).all()


def test_joint_search_metrics_match_direct_evaluation():
    """Re-evaluating a searched front deployment through joint_evaluate
    with its reported split reproduces the archived system metrics."""
    nets = [get_cnn("resnet50"), get_cnn("mobilenetv2")]
    dev = get_board("zc706")
    cfg = MultinetSearchConfig(pop_size=128, seed=9)
    res = joint_explore(nets, dev, 256, strategy="search", config=cfg)
    mt = make_multi_tables(nets)
    i = int(res.front[0])
    md = res.designs.take(np.array([i]))
    # re-feed the archived raw share genome of row i: metrics reproduce
    out = joint_evaluate(
        md, mt, dev,
        pes_shares=res.shares["pes"][i][None],
        buf_shares=res.shares["buf"][i][None],
        bw_shares=res.shares["bw"][i][None])
    np.testing.assert_allclose(
        float(np.asarray(out["worst_latency_s"])[0]),
        res.metrics["worst_latency_s"][i], rtol=1e-5)
    np.testing.assert_array_equal(
        np.asarray(out["pes_split"])[0], res.metrics["pes_split"][i])
