"""The coalescer's contract (docs/serving.md): every submitted spec is
answered exactly once, in order, from chunks that never exceed the
compiled tile ladder — and coalescing never forks a compile and never
spreads one request's NaN to its chunk-mates.

Planner properties run through ``hypo_fallback`` (real hypothesis when
installed) against ``validate_plan`` — arbitrary request streams, zero
violations.  The integration half drives a real :class:`Session`.
"""
from __future__ import annotations

import time
from unittest import mock

import numpy as np
import pytest
from hypo_fallback import given, settings, st

from repro.api import Session
from repro.cnn.registry import get_cnn
from repro.core import session as _session
from repro.core.coalesce import (ArrivalEstimator, ladder_pad,
                                 plan_megabatch, validate_plan)
from repro.fpga.boards import get_board

NET = "mobilenetv2"
BOARD = "zc706"


# --------------------------------------------------------------------------
# planner properties
# --------------------------------------------------------------------------
@st.composite
def _streams(draw):
    """(requests, chunk, tile, ndevices): arbitrary mixed-group request
    streams against arbitrary ladder geometry."""
    tile = draw(st.sampled_from([1, 8, 32]))
    ndevices = draw(st.sampled_from([1, 2, 4]))
    base = tile * ndevices
    chunk = base * draw(st.sampled_from([1, 2, 8]))
    n = draw(st.integers(min_value=1, max_value=12))
    reqs = [(draw(st.sampled_from(["g0", "g1", "g2"])),
             draw(st.integers(min_value=1, max_value=3 * chunk)))
            for _ in range(n)]
    return reqs, chunk, tile, ndevices


@settings(max_examples=60, deadline=None)
@given(_streams())
def test_plan_sound_for_arbitrary_streams(stream):
    """Exactly-once coverage in order, one group per chunk, every pad on
    the ladder and under the compiled chunk — for any stream."""
    reqs, chunk, tile, nd = stream
    plan = plan_megabatch(reqs, chunk, tile, nd)
    assert validate_plan(plan, reqs, chunk, tile, nd) == []
    total = sum(size for _, size in reqs)
    assert sum(c.rows for c in plan.chunks) == total
    assert plan.shared_pad <= chunk


@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=1, max_value=4096),
       st.sampled_from([1, 2, 8, 32]),
       st.sampled_from([1, 2, 4]))
def test_ladder_pad_is_ladder_shape(rows, tile, nd):
    chunk = 4096
    pad = ladder_pad(rows, chunk, tile, nd)
    assert rows <= pad <= chunk
    if pad < chunk:
        # exactly tile x nd x 2^k: dividing out tile x nd leaves 2^k
        q = pad // (tile * nd)
        assert pad == q * tile * nd and q & (q - 1) == 0


def test_ladder_pad_rejects_oversized_rows():
    with pytest.raises(ValueError, match="exceed"):
        ladder_pad(33, 32, 8)


def test_plan_merges_tiny_and_splits_oversized():
    reqs = [("g", 1), ("g", 1), ("g", 1), ("g", 70)]
    plan = plan_megabatch(reqs, chunk=32, tile=8)
    assert validate_plan(plan, reqs, 32, 8) == []
    # the three probes and the split request's head share chunks
    assert plan.merges >= 3
    assert plan.splits == 1          # only the 70-spec request splits
    assert all(c.pad <= 32 for c in plan.chunks)


def test_plan_never_mixes_groups():
    reqs = [("a", 2), ("b", 2), ("a", 2)]
    plan = plan_megabatch(reqs, chunk=32, tile=8)
    for c in plan.chunks:
        assert len({c.group}) == 1
    # same-group requests merged; the other group stayed apart
    assert plan.merges == 2
    assert len(plan.chunks) == 2


def test_plan_rejects_empty_request():
    with pytest.raises(ValueError, match="size 0"):
        plan_megabatch([("g", 0)], chunk=32, tile=8)


@settings(max_examples=30, deadline=None)
@given(st.floats(min_value=1e-4, max_value=0.2),
       st.floats(min_value=0.001, max_value=0.1))
def test_adaptive_linger_clamped(dt, max_s):
    """Linger always lands in [0, max_s], and a constant arrival rate
    converges it to gain x dt (capped)."""
    est = ArrivalEstimator()
    assert est.linger(max_s) == max_s       # cold queue: full window
    t = 0.0
    for _ in range(64):
        est.observe(t)
        t += dt
        assert 0.0 <= est.linger(max_s) <= max_s
    want = min(est.gain * dt, max_s)
    assert est.linger(max_s) == pytest.approx(want, rel=0.05)


def test_adaptive_linger_tracks_rate_change():
    est = ArrivalEstimator()
    t = 0.0
    for _ in range(32):
        est.observe(t)
        t += 0.1
    slow = est.linger(1.0)
    for _ in range(64):
        est.observe(t)
        t += 0.001
    assert est.linger(1.0) < slow           # hot stream shrinks the wait


# --------------------------------------------------------------------------
# integration: a real session's drain
# --------------------------------------------------------------------------
def _specs(k: int):
    return [f"{{L1-Last:CE1-CE{1 + (i % 6)}}}" for i in range(k)]


def test_coalescing_never_forks_compiles_and_is_bit_identical():
    """Tiny same-net probes merged into one chunk reuse the warmed
    compiled program (compile-miss total unchanged) and reproduce the
    uncoalesced results bit-for-bit."""
    net, dev = get_cnn(NET), get_board(BOARD)
    ses = Session(dev, linger_s=0.25)
    want = ses.evaluate(_specs(8), net)      # warms tables + ladder shape
    before = ses.compile_stats()["total"]
    futs = [ses.submit([s], net) for s in _specs(8)]
    outs = [f.result(timeout=300) for f in futs]
    assert ses.compile_stats()["total"] == before
    assert ses.stats.coalesced_merges >= 2
    assert ses.stats.coalesced_chunks >= 1
    for i, out in enumerate(outs):
        for k in want:
            np.testing.assert_array_equal(np.asarray(out[k]),
                                          np.asarray(want[k][i]))
    ses.close()


def test_split_request_reassembles_in_order():
    """A request larger than the compiled chunk splits, evaluates and
    concatenates back in spec order, bit-identical to the direct path."""
    net, dev = get_cnn(NET), get_board(BOARD)
    ses = Session(dev, chunk=32, linger_s=0.05)
    specs = _specs(70)
    out = ses.submit(specs, net).result(timeout=300)
    assert ses.stats.coalesced_splits >= 1
    want = ses.evaluate(specs, net)
    for k in want:
        np.testing.assert_array_equal(np.asarray(out[k]),
                                      np.asarray(want[k]))
    ses.close()


def test_merged_chunk_nan_fails_only_owner_row():
    """Within one merged chunk, a NaN in request A's rows fails A's
    future only — B (same chunk) still delivers."""
    net, dev = get_cnn(NET), get_board(BOARD)
    ses = Session(dev, linger_s=0.4)
    want = ses.evaluate(_specs(2), net)      # warm, and the reference
    real = _session._evaluate_specs_multi

    def poison_first_row(jobs, *a, **kw):
        outs = real(jobs, *a, **kw)
        poisoned = dict(outs[0])
        lat = np.asarray(poisoned["latency_s"]).copy()
        lat[0] = np.nan                      # request A owns row 0
        poisoned["latency_s"] = lat
        return [poisoned] + list(outs[1:])

    from repro.core.resilience import EvalError
    with mock.patch.object(_session, "_evaluate_specs_multi",
                           side_effect=poison_first_row):
        f_a = ses.submit([_specs(2)[0]], net)
        time.sleep(0.05)
        f_b = ses.submit([_specs(2)[1]], net)
        with pytest.raises(EvalError, match="non-finite"):
            f_a.result(timeout=300)
        out_b = f_b.result(timeout=300)
    assert ses.stats.coalesced_merges >= 2   # they shared a chunk
    for k in want:
        np.testing.assert_array_equal(np.asarray(out_b[k]),
                                      np.asarray(want[k][1]))
    ses.close()


def test_coalesce_off_reproduces_legacy_drain():
    """coalesce=False restores one-padded-chunk-per-request, still
    bit-identical."""
    net, dev = get_cnn(NET), get_board(BOARD)
    ses = Session(dev, linger_s=0.1, coalesce=False)
    futs = [ses.submit([s], net) for s in _specs(4)]
    outs = [f.result(timeout=300) for f in futs]
    assert ses.stats.coalesced_chunks == 0
    assert ses.stats.coalesced_merges == 0
    want = ses.evaluate(_specs(4), net)
    for i, out in enumerate(outs):
        for k in want:
            np.testing.assert_array_equal(np.asarray(out[k]),
                                          np.asarray(want[k][i]))
    ses.close()
