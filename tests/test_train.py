"""Training substrate: convergence, checkpoint fault tolerance, elastic
resharding, gradient compression."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.data.pipeline import synth_batch
from repro.models.registry import get_model
from repro.train import checkpoint as ckpt
from repro.train.optimizer import make_optimizer
from repro.train.train_step import (TrainState, init_residuals,
                                    make_compressed_train_step,
                                    make_train_step)

SHAPE = ShapeSpec("t", "train", 32, 8)


def _setup(arch="llama3.2-1b", rt=None, **opt_kw):
    cfg = get_config(arch).reduced()
    api = get_model(cfg)
    opt = make_optimizer("adamw", peak_lr=3e-3, warmup=5, total_steps=200,
                         **opt_kw)
    params = api.init(jax.random.key(0))
    state = TrainState(params=params, opt=opt.init(params),
                       step=jnp.zeros((), jnp.int32))
    return cfg, api, opt, state


def _run(step_fn, state, cfg, n, start=0):
    losses = []
    for i in range(start, start + n):
        batch = jax.tree.map(jnp.asarray, synth_batch(cfg, SHAPE, i))
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
    return state, losses


def test_loss_decreases(local_rt):
    cfg, api, opt, state = _setup(rt=local_rt)
    step = jax.jit(make_train_step(api, local_rt, opt), donate_argnums=(0,))
    state, losses = _run(step, state, cfg, 30)
    assert losses[-1] < losses[0] * 0.9
    assert int(state.step) == 30


def test_accum_matches_bigbatch(local_rt):
    """2 microbatches of B/2 == one batch of B (same grads modulo fp)."""
    cfg, api, opt, state = _setup(rt=local_rt)
    s1 = jax.jit(make_train_step(api, local_rt, opt))
    s2 = jax.jit(make_train_step(api, local_rt, opt, accum=2))
    batch = jax.tree.map(jnp.asarray, synth_batch(cfg, SHAPE, 0))
    st1, m1 = s1(state, batch)
    st2, m2 = s2(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=2e-2)
    l1 = jax.tree.leaves(st1.params)[3]
    l2 = jax.tree.leaves(st2.params)[3]
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32), atol=2e-2)


def test_factored_no_momentum_state_is_smaller():
    _, api, opt_full, state_full = _setup()
    _, _, opt_fac, _ = _setup(factored=True, momentum=False,
                              state_dtype="bfloat16")
    params = state_full.params
    full = sum(l.size * l.dtype.itemsize
               for l in jax.tree.leaves(opt_full.init(params)))
    fac = sum(l.size * l.dtype.itemsize
              for l in jax.tree.leaves(opt_fac.init(params)))
    assert fac < full * 0.30   # momentum dropped + v factored + bf16


def test_checkpoint_crash_recovery(tmp_path, local_rt):
    cfg, api, opt, state = _setup(rt=local_rt)
    step = jax.jit(make_train_step(api, local_rt, opt))
    state, _ = _run(step, state, cfg, 10)
    ckpt.save(str(tmp_path), 10, state)
    state, _ = _run(step, state, cfg, 3, start=10)   # "lost" work
    # partial (uncommitted) write must be ignored
    os.makedirs(tmp_path / "step_00000013", exist_ok=True)
    (tmp_path / "step_00000013" / "arrays.npz").write_bytes(b"garbage")
    assert ckpt.latest_step(str(tmp_path)) == 10
    restored = ckpt.restore(str(tmp_path), jax.eval_shape(lambda: state))
    assert int(restored.step) == 10
    # bit-exact params restore (bf16 stored as raw bits)
    def eq(a, b):
        np.testing.assert_array_equal(np.asarray(a).view(np.uint8),
                                      np.asarray(b).view(np.uint8))
    state10, _ = _run(step, restored, cfg, 0)


def test_checkpoint_elastic_reshard(tmp_path, local_rt, host_mesh):
    """Restore under a different sharding (elastic re-mesh)."""
    cfg, api, opt, state = _setup(rt=local_rt)
    ckpt.save(str(tmp_path), 1, state)
    sharding = jax.tree.map(
        lambda _: NamedSharding(host_mesh, P()), state)
    restored = ckpt.restore(str(tmp_path), jax.eval_shape(lambda: state),
                            shardings=sharding)
    for a, b in zip(jax.tree.leaves(state.params),
                    jax.tree.leaves(restored.params)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_compressed_ddp_close_to_fp32(host_mesh):
    """int8 error-feedback DDP tracks the fp32 loss curve."""
    from repro.models.runtime import Runtime
    rt = Runtime(mesh=host_mesh, dp_axes=("data",))
    cfg, api, opt, state0 = _setup(rt=rt)

    plain = jax.jit(make_train_step(api, rt, opt))
    comp_raw = make_compressed_train_step(api, rt, opt, axis="data",
                                          n_shards=host_mesh.shape["data"])
    comp = jax.jit(shard_map(
        comp_raw, mesh=host_mesh,
        in_specs=(P(), P(), P("data")),
        out_specs=(P(), P(), P()), check_vma=False))

    state_a = state0
    state_b = state0
    res = init_residuals(state0.params)
    la = lb = None
    for i in range(12):
        batch = jax.tree.map(jnp.asarray, synth_batch(cfg, SHAPE, i))
        state_a, ma = plain(state_a, batch)
        state_b, res, mb = comp(state_b, res, batch)
        la, lb = float(ma["loss"]), float(mb["loss"])
    assert lb < 6.0 and abs(la - lb) < 0.35


def test_compressed_wire_bytes_4x_smaller(host_mesh):
    """The compressed step's collective operand bytes are ~4x smaller than
    fp32 ring all-reduce of the same gradients (HLO-level check)."""
    from repro.models.runtime import Runtime
    from repro.tpu.hlo_walk import walk
    rt = Runtime(mesh=host_mesh, dp_axes=("data",))
    cfg, api, opt, state = _setup(rt=rt)
    comp_raw = make_compressed_train_step(api, rt, opt, axis="data",
                                          n_shards=host_mesh.shape["data"])
    comp = jax.jit(shard_map(
        comp_raw, mesh=host_mesh,
        in_specs=(P(), P(), P("data")),
        out_specs=(P(), P(), P()), check_vma=False))
    res = init_residuals(state.params)
    batch = jax.tree.map(jnp.asarray, synth_batch(cfg, SHAPE, 0))
    txt = comp.lower(state, res, batch).compile().as_text()
    n_params = sum(l.size for l in jax.tree.leaves(state.params))
    costs = walk(txt)
    a2a = costs.coll_operand.get("all-to-all", 0.0)
    ag = costs.coll_operand.get("all-gather", 0.0)
    # int8 wire payload ≈ 2 B/param total vs 4 B/param fp32 operand
    assert 0 < a2a + ag < n_params * 3.0
